//! The actual byte wire format for sparsified gradients (what the simulated
//! All-Reduce ships between workers).
//!
//! ```text
//! offset  size  field
//! 0       4     magic "GSPR"
//! 4       1     version (1)
//! 5       1     encoding (0 = Indexed, 1 = DenseSymbols, 2 = IndexedRice)
//! 6       1     Rice parameter k for the QA index stream (must be 0 unless enc = 2)
//! 7       1     Rice parameter k for the QB index stream (must be 0 unless enc = 2)
//! 8       4     d            (u32 LE)
//! 12      4     nnz_a        (u32 LE)
//! 16      4     nnz_b        (u32 LE)
//! 20      4     shared_mag   (f32 LE, = 1/λ)
//! 24      ...   payload
//! ```
//!
//! * Indexed payload: `nnz_a × (u32 index, f32 value)`, then `nnz_b × u32`
//!   QB indices, then `⌈nnz_b/8⌉` bytes of QB sign bitmap (bit set ⇒
//!   negative).
//! * DenseSymbols payload: `⌈d/4⌉` bytes of 2-bit symbols in coordinate
//!   order (0 dropped, 1 = +shared, 2 = −shared, 3 = exact), then `nnz_a`
//!   f32 values for the exact coordinates in ascending coordinate order.
//! * IndexedRice payload (the `Entropy` codec's layout): `nnz_a` f32 values
//!   in ascending coordinate order, `⌈nnz_b/8⌉` bytes of QB sign bitmap,
//!   then one [`rice`]-coded bit stream holding the QA index gaps followed
//!   by the QB index gaps (per-stream parameters from header bytes 6–7),
//!   zero-padded to a byte boundary.
//!
//! [`encode`] picks the smaller of the two [`WireCodec::Raw`] encodings,
//! exactly like the `min(·,·)` in Theorem 4; [`encode_with`] under
//! [`WireCodec::Entropy`] additionally considers `IndexedRice` and takes
//! the cheapest of the three, so an entropy-coded message is never larger
//! than the raw one.

use super::rice::{self, BitReader, BitWriter, RiceError, MAX_RICE_PARAM};
use crate::sparsify::SparseGrad;

pub const MAGIC: &[u8; 4] = b"GSPR";
pub const VERSION: u8 = 1;
/// Fixed header length in bytes.
pub const HEADER_LEN: usize = 24;

/// Which payload layout a message uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Encoding {
    Indexed = 0,
    DenseSymbols = 1,
    /// Delta + Golomb-Rice coded index streams (`Entropy` codec only).
    IndexedRice = 2,
}

/// The negotiated wire codec: which encodings an encoder may emit. Both
/// sides of a link must agree (the transport handshake carries it, like the
/// protocol version), so a decoder never has to guess.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireCodec {
    /// PR-2 format: raw `u32` indices (`Indexed` | `DenseSymbols`).
    #[default]
    Raw = 0,
    /// Delta + Golomb-Rice index streams when cheaper
    /// (`Indexed` | `DenseSymbols` | `IndexedRice`).
    Entropy = 1,
}

impl WireCodec {
    pub fn all() -> &'static [WireCodec] {
        &[WireCodec::Raw, WireCodec::Entropy]
    }

    pub fn parse(s: &str) -> Option<WireCodec> {
        Some(match s.to_ascii_lowercase().as_str() {
            "raw" => WireCodec::Raw,
            "entropy" | "rice" => WireCodec::Entropy,
            _ => return None,
        })
    }

    pub fn from_u8(v: u8) -> Option<WireCodec> {
        Some(match v {
            0 => WireCodec::Raw,
            1 => WireCodec::Entropy,
            _ => return None,
        })
    }

    /// Stable index into per-codec metric columns.
    pub fn index(self) -> usize {
        self as usize
    }

    /// The codec named by `GSPARSE_CODEC` (default [`WireCodec::Raw`] when
    /// unset) — how the CI `codec: [raw, entropy]` matrix steers the shared
    /// suites. Panics on an unrecognized value: a typo in the matrix must
    /// fail the leg loudly, not silently fall back to `Raw` and turn the
    /// entropy leg into a no-op.
    pub fn from_env() -> WireCodec {
        match std::env::var("GSPARSE_CODEC") {
            Err(_) => WireCodec::Raw,
            Ok(s) => WireCodec::parse(&s)
                .unwrap_or_else(|| panic!("GSPARSE_CODEC={s:?} is not a wire codec (raw|entropy)")),
        }
    }
}

impl std::fmt::Display for WireCodec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            WireCodec::Raw => "raw",
            WireCodec::Entropy => "entropy",
        })
    }
}

/// Wire-format decode errors. (`Display`/`Error` are hand-written: the
/// offline image has no `thiserror`.)
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WireError {
    Truncated(usize),
    BadMagic,
    BadVersion(u8),
    BadEncoding(u8),
    LengthMismatch { expected: usize, got: usize },
    IndexOutOfBounds { index: u32, d: u32 },
    IndicesNotSorted(usize),
    /// Header claims more survivors than coordinates (`na + nb > d`) — an
    /// adversarial or corrupted message; rejected before any buffer grows.
    CountsExceedDim { na: u32, nb: u32, d: u32 },
    /// `shared_mag` is NaN or ±∞ — decoding would poison every QB
    /// coordinate, so the message is rejected at the header.
    NonFiniteSharedMag(f32),
    /// An `IndexedRice` header carries a Rice parameter ≥ 32 — no `u32` gap
    /// needs one, so it is adversarial; rejected at the header.
    BadRiceParam(u8),
    /// The Rice bit stream itself is malformed: truncated mid-codeword, a
    /// unary quotient too large for the dimension, or non-zero padding
    /// bits after the final codeword (only one byte form is canonical).
    BadRiceStream(&'static str),
    /// Header bytes 6–7 must be zero for non-Rice encodings — enforced so
    /// every message has exactly one canonical byte form.
    NonZeroReserved(u8),
    /// A `WireBatch` per-layer Rice parameter delta byte is structurally
    /// invalid: flagged on a non-Rice sub-message, present in a v1 batch,
    /// all-zero (the pooled form is canonical for zero deltas), or pushing
    /// an effective parameter outside `[0, MAX_RICE_PARAM]`.
    BadParamDelta(u8),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated(n) => write!(f, "message too short: {n} bytes"),
            WireError::BadMagic => write!(f, "bad magic"),
            WireError::BadVersion(v) => write!(f, "unsupported version {v}"),
            WireError::BadEncoding(e) => write!(f, "unknown encoding {e}"),
            WireError::LengthMismatch { expected, got } => {
                write!(f, "payload length mismatch: expected {expected}, got {got}")
            }
            WireError::IndexOutOfBounds { index, d } => {
                write!(f, "index {index} out of bounds (d = {d})")
            }
            WireError::IndicesNotSorted(pos) => {
                write!(f, "indices not strictly ascending at position {pos}")
            }
            WireError::CountsExceedDim { na, nb, d } => {
                write!(f, "survivor counts {na} + {nb} exceed dimension {d}")
            }
            WireError::NonFiniteSharedMag(v) => {
                write!(f, "shared magnitude {v} is not finite")
            }
            WireError::BadRiceParam(k) => {
                write!(f, "rice parameter {k} out of range (max {MAX_RICE_PARAM})")
            }
            WireError::BadRiceStream(why) => write!(f, "malformed rice stream: {why}"),
            WireError::NonZeroReserved(v) => {
                write!(f, "reserved header byte must be zero, got {v}")
            }
            WireError::BadParamDelta(b) => {
                write!(f, "invalid rice parameter delta byte {b:#04x}")
            }
        }
    }
}

impl std::error::Error for WireError {}

pub(crate) fn indexed_payload_len(nnz_a: usize, nnz_b: usize) -> usize {
    nnz_a * 8 + nnz_b * 4 + nnz_b.div_ceil(8)
}

pub(crate) fn dense_payload_len(d: usize, nnz_a: usize) -> usize {
    d.div_ceil(4) + nnz_a * 4
}

pub(crate) fn rice_payload_len(nnz_a: usize, nnz_b: usize, stream_bits: u64) -> usize {
    nnz_a * 4 + nnz_b.div_ceil(8) + stream_bits.div_ceil(8) as usize
}

/// Index gaps of a strictly-ascending `(index, _)` slice: first element is
/// the index itself, later ones `i_j − i_{j−1} − 1`.
pub(crate) fn gaps_of<T: Copy>(pairs: &[(u32, T)]) -> impl Iterator<Item = u32> + '_ {
    pairs.iter().enumerate().map(|(j, &(i, _))| {
        if j == 0 {
            i
        } else {
            i - pairs[j - 1].0 - 1
        }
    })
}

/// The per-stream Rice parameters and total stream bits the `Entropy` codec
/// would use for `sg` — the parameter search already computes the winning
/// cost, so no extra pass over the indices is needed. No allocation.
fn rice_plan(sg: &SparseGrad) -> (u8, u8, u64) {
    let (ka, bits_a) = rice::choose_param(|| gaps_of(&sg.exact));
    let (kb, bits_b) = rice::choose_param(|| gaps_of(&sg.shared));
    (ka, kb, bits_a + bits_b)
}

/// Byte length [`encode`] will produce for `sg` (header + cheaper payload).
pub fn encoded_len(sg: &SparseGrad) -> usize {
    encoded_len_with(sg, WireCodec::Raw)
}

/// Byte length [`encode_with`] will produce for `sg` under `codec`.
pub fn encoded_len_with(sg: &SparseGrad, codec: WireCodec) -> usize {
    let raw = indexed_payload_len(sg.exact.len(), sg.shared.len())
        .min(dense_payload_len(sg.d as usize, sg.exact.len()));
    let payload = match codec {
        WireCodec::Raw => raw,
        WireCodec::Entropy => {
            let (_, _, bits) = rice_plan(sg);
            raw.min(rice_payload_len(sg.exact.len(), sg.shared.len(), bits))
        }
    };
    HEADER_LEN + payload
}

/// Encode under the [`WireCodec::Raw`] codec (the PR-2 wire format). See
/// [`encode_with`].
pub fn encode(sg: &SparseGrad, out: &mut Vec<u8>) -> Encoding {
    encode_with(sg, WireCodec::Raw, out)
}

/// Encode into `out` (cleared first; capacity is reused across calls, so a
/// steady-state encode performs no heap allocation). The codec bounds the
/// encodings considered; the cheapest admissible one is chosen and
/// returned, so `Entropy` output is never larger than `Raw` output for the
/// same message.
pub fn encode_with(sg: &SparseGrad, codec: WireCodec, out: &mut Vec<u8>) -> Encoding {
    let mut trace_span = crate::trace::span(crate::trace::Stage::Encode);
    let d = sg.d as usize;
    let (na, nb) = (sg.exact.len(), sg.shared.len());
    // Header math lives in one place: compute every admissible payload
    // length once, pick the cheapest encoding, and reserve via the same
    // `encoded_len_with` formula the tests check against.
    let indexed_len = indexed_payload_len(na, nb);
    let dense_len = dense_payload_len(d, na);
    let raw_len = indexed_len.min(dense_len);
    let (ka, kb, rice_len) = match codec {
        WireCodec::Raw => (0, 0, usize::MAX),
        WireCodec::Entropy => {
            let (ka, kb, bits) = rice_plan(sg);
            (ka, kb, rice_payload_len(na, nb, bits))
        }
    };
    let enc = if rice_len < raw_len {
        Encoding::IndexedRice
    } else if indexed_len <= dense_len {
        Encoding::Indexed
    } else {
        Encoding::DenseSymbols
    };
    out.clear();
    out.reserve(HEADER_LEN + rice_len.min(raw_len));
    out.extend_from_slice(MAGIC);
    out.push(VERSION);
    out.push(enc as u8);
    if enc == Encoding::IndexedRice {
        out.push(ka);
        out.push(kb);
    } else {
        out.extend_from_slice(&[0, 0]);
    }
    out.extend_from_slice(&(sg.d).to_le_bytes());
    out.extend_from_slice(&(na as u32).to_le_bytes());
    out.extend_from_slice(&(nb as u32).to_le_bytes());
    out.extend_from_slice(&sg.shared_mag.to_le_bytes());

    write_payload(sg, enc, ka, kb, out);
    debug_assert_eq!(out.len(), encoded_len_with(sg, codec));
    trace_span.bytes(out.len() as u64);
    enc
}

/// Append the payload bytes of `sg` under `enc` to `out` (no header). The
/// Rice parameters are the *caller's*: the single-message encoder passes
/// the per-message optimum, the [`super::batch`] encoder the batch-shared
/// pair — the byte layout is identical either way.
pub(crate) fn write_payload(sg: &SparseGrad, enc: Encoding, ka: u8, kb: u8, out: &mut Vec<u8>) {
    let d = sg.d as usize;
    let nb = sg.shared.len();
    match enc {
        Encoding::Indexed => {
            // Pre-size once and write at offsets: avoids per-entry capacity
            // checks (measured 2.5x on the encode hot path — see
            // EXPERIMENTS.md §Perf).
            let indexed_len = indexed_payload_len(sg.exact.len(), nb);
            let start = out.len();
            out.resize(start + indexed_len, 0);
            let payload = &mut out[start..];
            let mut off = 0;
            for &(i, v) in &sg.exact {
                payload[off..off + 4].copy_from_slice(&i.to_le_bytes());
                payload[off + 4..off + 8].copy_from_slice(&v.to_le_bytes());
                off += 8;
            }
            for &(i, _) in &sg.shared {
                payload[off..off + 4].copy_from_slice(&i.to_le_bytes());
                off += 4;
            }
            for (pos, &(_, neg)) in sg.shared.iter().enumerate() {
                if neg {
                    payload[off + pos / 8] |= 1 << (pos % 8);
                }
            }
        }
        Encoding::DenseSymbols => {
            // 2-bit symbols, written in place in the output buffer (no
            // temporary allocation on the hot path).
            let sym_start = out.len();
            out.resize(sym_start + d.div_ceil(4), 0);
            {
                let symbols = &mut out[sym_start..];
                for &(i, _) in &sg.exact {
                    let i = i as usize;
                    symbols[i / 4] |= 0b11 << (2 * (i % 4));
                }
                for &(i, neg) in &sg.shared {
                    let i = i as usize;
                    let sym = if neg { 0b10 } else { 0b01 };
                    symbols[i / 4] |= sym << (2 * (i % 4));
                }
            }
            for &(_, v) in &sg.exact {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Encoding::IndexedRice => {
            // QA values first (fixed width, so the variable-length bit
            // stream can simply run to the end of the payload), then the
            // sign bitmap, then the two gap streams back to back.
            for &(_, v) in &sg.exact {
                out.extend_from_slice(&v.to_le_bytes());
            }
            let bm_start = out.len();
            out.resize(bm_start + nb.div_ceil(8), 0);
            for (pos, &(_, neg)) in sg.shared.iter().enumerate() {
                if neg {
                    out[bm_start + pos / 8] |= 1 << (pos % 8);
                }
            }
            let mut w = BitWriter::new(out);
            for gap in gaps_of(&sg.exact) {
                w.write_rice(gap, ka as u32);
            }
            for gap in gaps_of(&sg.shared) {
                w.write_rice(gap, kb as u32);
            }
            w.finish();
        }
    }
}

/// Decode a wire message back into a fresh [`SparseGrad`]. Validates
/// structure and rejects malformed input (the failure-injection tests
/// exercise every arm).
pub fn decode(buf: &[u8]) -> Result<SparseGrad, WireError> {
    let mut sg = SparseGrad::empty(0);
    decode_into(buf, &mut sg)?;
    Ok(sg)
}

/// Decode into a caller-provided [`SparseGrad`], reusing its buffers (the
/// allocation-free path the [`crate::comm::Aggregator`] and coordinator use
/// every round). On error `sg` may hold partially-decoded content and must
/// not be interpreted.
pub fn decode_into(buf: &[u8], sg: &mut SparseGrad) -> Result<(), WireError> {
    let mut trace_span = crate::trace::span(crate::trace::Stage::Decode);
    trace_span.bytes(buf.len() as u64);
    if buf.len() < HEADER_LEN {
        return Err(WireError::Truncated(buf.len()));
    }
    if &buf[0..4] != MAGIC {
        return Err(WireError::BadMagic);
    }
    if buf[4] != VERSION {
        return Err(WireError::BadVersion(buf[4]));
    }
    let enc = match buf[5] {
        0 => Encoding::Indexed,
        1 => Encoding::DenseSymbols,
        2 => Encoding::IndexedRice,
        e => return Err(WireError::BadEncoding(e)),
    };
    // Bytes 6–7 carry the Rice parameters for enc = 2 and must be zero
    // otherwise — decode enforces it so each message has exactly one
    // canonical byte form (mirroring the rice-padding canonicality check).
    if enc != Encoding::IndexedRice {
        for &b in &buf[6..8] {
            if b != 0 {
                return Err(WireError::NonZeroReserved(b));
            }
        }
    }
    let d = u32::from_le_bytes(buf[8..12].try_into().unwrap());
    let na = u32::from_le_bytes(buf[12..16].try_into().unwrap()) as usize;
    let nb = u32::from_le_bytes(buf[16..20].try_into().unwrap()) as usize;
    let shared_mag = f32::from_le_bytes(buf[20..24].try_into().unwrap());
    // Adversarial-header gates (bytes may arrive from a socket): the
    // survivor counts must fit the dimension — checked before any reserve,
    // so a hostile header cannot trigger a huge allocation — and the shared
    // magnitude must be finite, or every QB coordinate would decode to
    // NaN/∞ and poison the weight vector.
    if na as u64 + nb as u64 > d as u64 {
        return Err(WireError::CountsExceedDim {
            na: na as u32,
            nb: nb as u32,
            d,
        });
    }
    if !shared_mag.is_finite() {
        return Err(WireError::NonFiniteSharedMag(shared_mag));
    }
    let payload = &buf[HEADER_LEN..];

    sg.reset(d as usize);
    sg.shared_mag = shared_mag;

    let (ka, kb) = (buf[6], buf[7]);
    if enc == Encoding::IndexedRice {
        // Validated here (not in `read_payload`) so every header-derived
        // gate still runs before any buffer grows.
        if ka > MAX_RICE_PARAM {
            return Err(WireError::BadRiceParam(ka));
        }
        if kb > MAX_RICE_PARAM {
            return Err(WireError::BadRiceParam(kb));
        }
    }
    let consumed = read_payload(enc, d, na, nb, ka, kb, payload, sg)?;
    if consumed != payload.len() {
        return Err(WireError::LengthMismatch {
            expected: consumed,
            got: payload.len(),
        });
    }
    Ok(())
}

/// Decode one payload under `enc` from the front of `buf` into `sg`
/// (already reset to dimension `d` with its shared magnitude set), and
/// return the number of bytes consumed. `buf` may extend past the payload —
/// the [`super::batch`] decoder hands the rest of the batch buffer — so
/// fixed-layout encodings consume exactly their computed length and the
/// Rice encoding consumes exactly its codewords plus canonical padding.
/// The caller has validated the header fields (`na + nb ≤ d`, finite
/// magnitude, Rice parameters in range).
#[allow(clippy::too_many_arguments)] // one flat call per decoded sub-message
pub(crate) fn read_payload(
    enc: Encoding,
    d: u32,
    na: usize,
    nb: usize,
    ka: u8,
    kb: u8,
    buf: &[u8],
    sg: &mut SparseGrad,
) -> Result<usize, WireError> {
    match enc {
        Encoding::Indexed => {
            let expected = indexed_payload_len(na, nb);
            if buf.len() < expected {
                return Err(WireError::LengthMismatch {
                    expected,
                    got: buf.len(),
                });
            }
            let payload = &buf[..expected];
            let mut off = 0;
            sg.exact.reserve(na);
            let mut prev: i64 = -1;
            for pos in 0..na {
                let i = u32::from_le_bytes(payload[off..off + 4].try_into().unwrap());
                let v = f32::from_le_bytes(payload[off + 4..off + 8].try_into().unwrap());
                off += 8;
                if i >= d {
                    return Err(WireError::IndexOutOfBounds { index: i, d });
                }
                if (i as i64) <= prev {
                    return Err(WireError::IndicesNotSorted(pos));
                }
                prev = i as i64;
                sg.exact.push((i, v));
            }
            let idx_end = off + nb * 4;
            let bitmap = &payload[idx_end..];
            sg.shared.reserve(nb);
            prev = -1;
            for pos in 0..nb {
                let i =
                    u32::from_le_bytes(payload[off + pos * 4..off + pos * 4 + 4].try_into().unwrap());
                if i >= d {
                    return Err(WireError::IndexOutOfBounds { index: i, d });
                }
                if (i as i64) <= prev {
                    return Err(WireError::IndicesNotSorted(pos));
                }
                prev = i as i64;
                let neg = bitmap[pos / 8] & (1 << (pos % 8)) != 0;
                sg.shared.push((i, neg));
            }
            Ok(expected)
        }
        Encoding::DenseSymbols => {
            let expected = dense_payload_len(d as usize, na);
            if buf.len() < expected {
                return Err(WireError::LengthMismatch {
                    expected,
                    got: buf.len(),
                });
            }
            let payload = &buf[..expected];
            let symbols = &payload[..(d as usize).div_ceil(4)];
            let values = &payload[(d as usize).div_ceil(4)..];
            sg.exact.reserve(na);
            sg.shared.reserve(nb);
            let mut voff = 0;
            // Byte-at-a-time with a zero-byte fast path: 4 coordinates per
            // iteration, and all-dropped groups cost one compare.
            for (bi, &byte) in symbols.iter().enumerate() {
                if byte == 0 {
                    continue;
                }
                let base = (bi * 4) as u32;
                let mut rest = byte;
                for lane in 0..4u32 {
                    let sym = rest & 0b11;
                    rest >>= 2;
                    if sym == 0 {
                        continue;
                    }
                    let i = base + lane;
                    if i >= d {
                        break;
                    }
                    match sym {
                        0b01 => sg.shared.push((i, false)),
                        0b10 => sg.shared.push((i, true)),
                        _ => {
                            if voff + 4 > values.len() {
                                return Err(WireError::LengthMismatch {
                                    expected,
                                    got: payload.len(),
                                });
                            }
                            let v =
                                f32::from_le_bytes(values[voff..voff + 4].try_into().unwrap());
                            voff += 4;
                            sg.exact.push((i, v));
                        }
                    }
                }
            }
            if sg.exact.len() != na || sg.shared.len() != nb {
                return Err(WireError::LengthMismatch {
                    expected: na + nb,
                    got: sg.exact.len() + sg.shared.len(),
                });
            }
            Ok(expected)
        }
        Encoding::IndexedRice => {
            // An empty message has no gap streams, and the encoder always
            // prefers the raw encodings for it (Rice is only chosen when
            // strictly smaller) — so an empty Rice payload is
            // non-canonical and would otherwise let the Rice-parameter
            // header bytes carry arbitrary values.
            if na == 0 && nb == 0 {
                return Err(WireError::BadRiceStream("empty rice message"));
            }
            // All header-derived gates have run before any buffer grows, in
            // the same spirit as `CountsExceedDim`: the caller validated
            // the Rice parameters, and the payload must be at least the
            // fixed part plus the provable minimum of `(k+1)` bits per
            // gap — so a hostile header cannot make the reserve below
            // exceed what the (frame-capped) payload itself already paid
            // for. The resulting decoded-memory amplification is bounded
            // and proportional: each QA entry is corroborated by ≥ 4
            // payload bytes and each QB entry by ≥ 2 payload bits (1
            // bitmap bit + ≥ 1 stream bit) — i.e. at most ~32 decoded
            // bytes per payload byte, the same exposure the 2-bit
            // DenseSymbols encoding has always had, never the unbounded
            // header-only reserve that `CountsExceedDim` guards against.
            let fixed = na * 4 + nb.div_ceil(8);
            let min_stream_bits = na as u64 * (ka as u64 + 1) + nb as u64 * (kb as u64 + 1);
            let min_len = fixed + min_stream_bits.div_ceil(8) as usize;
            if buf.len() < min_len {
                return Err(WireError::LengthMismatch {
                    expected: min_len,
                    got: buf.len(),
                });
            }
            let values = &buf[..na * 4];
            let bitmap = &buf[na * 4..fixed];
            let stream = &buf[fixed..];
            sg.exact.reserve(na);
            sg.shared.reserve(nb);
            let mut reader = BitReader::new(stream);
            let map_rice = |e: RiceError| match e {
                RiceError::Truncated => WireError::BadRiceStream("truncated"),
                RiceError::QuotientOverflow => WireError::BadRiceStream("quotient overflow"),
            };
            // Gaps accumulate to indices; a sum escaping the dimension is
            // an impossible message ("gap overflow past d").
            let (ka, kb) = (ka as u32, kb as u32);
            let mut prev: i64 = -1;
            for pos in 0..na {
                let gap = reader.read_rice(ka, d >> ka).map_err(map_rice)?;
                let idx = prev + 1 + gap as i64;
                if idx >= d as i64 {
                    return Err(WireError::IndexOutOfBounds {
                        index: idx.min(u32::MAX as i64) as u32,
                        d,
                    });
                }
                prev = idx;
                let v = f32::from_le_bytes(values[pos * 4..pos * 4 + 4].try_into().unwrap());
                sg.exact.push((idx as u32, v));
            }
            prev = -1;
            for pos in 0..nb {
                let gap = reader.read_rice(kb, d >> kb).map_err(map_rice)?;
                let idx = prev + 1 + gap as i64;
                if idx >= d as i64 {
                    return Err(WireError::IndexOutOfBounds {
                        index: idx.min(u32::MAX as i64) as u32,
                        d,
                    });
                }
                prev = idx;
                let neg = bitmap[pos / 8] & (1 << (pos % 8)) != 0;
                sg.shared.push((idx as u32, neg));
            }
            // Canonical form: the final partial byte's padding bits are
            // zero. (Whether trailing bytes may follow is the caller's
            // call: the single-message decoder requires the payload to end
            // exactly here, the batch decoder continues into the next
            // sub-message.)
            if !reader.padding_is_zero() {
                return Err(WireError::BadRiceStream("nonzero padding"));
            }
            Ok(fixed + reader.consumed_bytes())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngkit::RandArray;
    use crate::sparsify::{greedy_probs, sample_sparse};

    fn sample_message(d: usize, rho: f32, seed: u64) -> SparseGrad {
        let mut rng = crate::rngkit::Xoshiro256pp::seed_from_u64(seed);
        let g: Vec<f32> = (0..d).map(|_| (rng.next_gaussian() * 0.5) as f32).collect();
        let mut p = Vec::new();
        let pv = greedy_probs(&g, rho, 2, &mut p);
        let mut ra = RandArray::from_seed(seed ^ 1, 1 << 16);
        sample_sparse(&g, &p, pv.inv_lambda, &mut ra)
    }

    #[test]
    fn roundtrip_indexed() {
        let sg = sample_message(1024, 0.02, 40); // sparse -> indexed
        let mut buf = Vec::new();
        let enc = encode(&sg, &mut buf);
        assert_eq!(enc, Encoding::Indexed);
        assert_eq!(buf.len(), encoded_len(&sg));
        let back = decode(&buf).unwrap();
        assert_eq!(back, sg);
    }

    #[test]
    fn roundtrip_dense_symbols() {
        let sg = sample_message(256, 0.9, 41); // dense -> symbol coding
        let mut buf = Vec::new();
        let enc = encode(&sg, &mut buf);
        assert_eq!(enc, Encoding::DenseSymbols);
        let back = decode(&buf).unwrap();
        assert_eq!(back, sg);
    }

    #[test]
    fn empty_message_roundtrip() {
        let sg = SparseGrad::empty(100);
        let mut buf = Vec::new();
        encode(&sg, &mut buf);
        assert_eq!(decode(&buf).unwrap(), sg);
    }

    #[test]
    fn rejects_truncated() {
        let sg = sample_message(128, 0.1, 42);
        let mut buf = Vec::new();
        encode(&sg, &mut buf);
        assert_eq!(decode(&buf[..10]), Err(WireError::Truncated(10)));
        let err = decode(&buf[..buf.len() - 1]).unwrap_err();
        assert!(matches!(err, WireError::LengthMismatch { .. }), "{err:?}");
    }

    #[test]
    fn rejects_bad_magic_version_encoding() {
        let sg = sample_message(128, 0.1, 43);
        let mut buf = Vec::new();
        encode(&sg, &mut buf);
        let mut bad = buf.clone();
        bad[0] = b'X';
        assert_eq!(decode(&bad), Err(WireError::BadMagic));
        let mut bad = buf.clone();
        bad[4] = 9;
        assert_eq!(decode(&bad), Err(WireError::BadVersion(9)));
        let mut bad = buf.clone();
        bad[5] = 7;
        assert_eq!(decode(&bad), Err(WireError::BadEncoding(7)));
    }

    #[test]
    fn rejects_nonzero_reserved_bytes_on_non_rice_encodings() {
        // One canonical byte form per message: bytes 6–7 are Rice
        // parameters only for enc = 2 and must be zero otherwise.
        for (d, rho) in [(1024usize, 0.02f32), (256, 0.9)] {
            let sg = sample_message(d, rho, 45);
            let mut buf = Vec::new();
            let enc = encode(&sg, &mut buf);
            assert_ne!(enc, Encoding::IndexedRice);
            for slot in [6usize, 7] {
                let mut bad = buf.clone();
                bad[slot] = 3;
                assert_eq!(decode(&bad), Err(WireError::NonZeroReserved(3)));
            }
        }
    }

    #[test]
    fn rejects_out_of_bounds_index() {
        let mut sg = SparseGrad::empty(16);
        sg.exact.push((3, 1.0));
        let mut buf = Vec::new();
        encode(&sg, &mut buf);
        // Corrupt the index to 999 (little-endian at payload offset 0).
        buf[HEADER_LEN..HEADER_LEN + 4].copy_from_slice(&999u32.to_le_bytes());
        assert_eq!(
            decode(&buf),
            Err(WireError::IndexOutOfBounds { index: 999, d: 16 })
        );
    }

    #[test]
    fn rejects_unsorted_indices() {
        // d large enough that the Indexed encoding is chosen.
        let mut sg = SparseGrad::empty(1000);
        sg.exact.push((5, 1.0));
        sg.exact.push((9, 2.0));
        let mut buf = Vec::new();
        encode(&sg, &mut buf);
        // Swap index order.
        buf[HEADER_LEN..HEADER_LEN + 4].copy_from_slice(&9u32.to_le_bytes());
        buf[HEADER_LEN + 8..HEADER_LEN + 12].copy_from_slice(&5u32.to_le_bytes());
        assert!(matches!(
            decode(&buf),
            Err(WireError::IndicesNotSorted(_)) | Err(WireError::IndexOutOfBounds { .. })
        ));
    }

    #[test]
    fn rejects_counts_exceeding_dimension() {
        // Adversarial header: na + nb > d must be rejected *before* the
        // payload-length check (so no hostile reserve can happen either).
        let mut sg = SparseGrad::empty(16);
        sg.exact.push((3, 1.0));
        let mut buf = Vec::new();
        encode(&sg, &mut buf);
        buf[12..16].copy_from_slice(&12u32.to_le_bytes()); // na = 12
        buf[16..20].copy_from_slice(&5u32.to_le_bytes()); // nb = 5, 17 > 16
        assert_eq!(
            decode(&buf),
            Err(WireError::CountsExceedDim {
                na: 12,
                nb: 5,
                d: 16
            })
        );
        // Saturating case: both counts u32::MAX must not overflow the check.
        buf[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
        buf[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            decode(&buf),
            Err(WireError::CountsExceedDim { .. })
        ));
    }

    #[test]
    fn rejects_non_finite_shared_mag() {
        let mut sg = SparseGrad::empty(64);
        sg.exact.push((1, 2.0));
        sg.shared.push((5, false));
        sg.shared.push((9, true));
        sg.shared_mag = 0.5;
        let mut buf = Vec::new();
        encode(&sg, &mut buf);
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut corrupt = buf.clone();
            corrupt[20..24].copy_from_slice(&bad.to_le_bytes());
            assert!(
                matches!(
                    decode(&corrupt),
                    Err(WireError::NonFiniteSharedMag(_))
                ),
                "shared_mag {bad} must be rejected"
            );
        }
    }

    #[test]
    fn encoder_picks_smaller_encoding() {
        for (d, rho) in [(4096, 0.01f32), (128, 0.8), (512, 0.25), (64, 1.0)] {
            let sg = sample_message(d, rho, 44 + d as u64);
            let mut buf = Vec::new();
            encode(&sg, &mut buf);
            let indexed = HEADER_LEN + indexed_payload_len(sg.exact.len(), sg.shared.len());
            let dense = HEADER_LEN + dense_payload_len(d, sg.exact.len());
            assert_eq!(buf.len(), indexed.min(dense), "d={d} rho={rho}");
        }
    }

    #[test]
    fn property_dense_symbols_roundtrip_unaligned_d() {
        // DenseSymbols packs 4 coordinates per byte; d % 4 != 0 leaves a
        // partial final byte whose high lanes must be ignored on decode.
        crate::proptest_lite::run("dense-symbol roundtrip, d % 4 != 0", 64, |gen| {
            let d = gen.usize_in(1, 500) * 4 + gen.usize_in(1, 4); // never ≡ 0 (mod 4)
            assert_ne!(d % 4, 0);
            // High density forces the DenseSymbols encoding.
            let sg = {
                let mut rng = crate::rngkit::Xoshiro256pp::seed_from_u64(gen.u64());
                let g: Vec<f32> = (0..d).map(|_| (rng.next_gaussian() * 0.5) as f32).collect();
                let mut p = Vec::new();
                let pv = greedy_probs(&g, 0.95, 2, &mut p);
                let mut ra = RandArray::from_seed(gen.u64(), 1 << 14);
                sample_sparse(&g, &p, pv.inv_lambda, &mut ra)
            };
            let mut buf = Vec::new();
            let enc = encode(&sg, &mut buf);
            if enc != Encoding::DenseSymbols {
                return Err(format!("expected DenseSymbols at d={d}, got {enc:?}"));
            }
            if buf.len() != encoded_len(&sg) {
                return Err(format!("encoded_len {} != {}", encoded_len(&sg), buf.len()));
            }
            match decode(&buf) {
                Ok(back) if back == sg => Ok(()),
                Ok(_) => Err(format!("roundtrip not identical at d={d}")),
                Err(e) => Err(format!("decode failed at d={d}: {e}")),
            }
        });
    }

    #[test]
    fn property_empty_and_zero_gradient_messages() {
        // Zero gradients and empty messages must roundtrip at any d,
        // including d % 4 != 0 and d = 1.
        crate::proptest_lite::run("empty/zero-gradient roundtrip", 64, |gen| {
            let d = gen.usize_in(1, 3000);
            let sg = if gen.bool() {
                SparseGrad::empty(d)
            } else {
                // Zero gradient through the full solver + sampler pipeline.
                let g = vec![0.0f32; d];
                let mut p = Vec::new();
                let pv = greedy_probs(&g, 0.5, 2, &mut p);
                let mut ra = RandArray::from_seed(gen.u64(), 1 << 12);
                sample_sparse(&g, &p, pv.inv_lambda, &mut ra)
            };
            if sg.nnz() != 0 {
                return Err("zero gradient produced survivors".into());
            }
            let mut buf = Vec::new();
            encode(&sg, &mut buf);
            match decode(&buf) {
                Ok(back) if back == sg => Ok(()),
                Ok(_) => Err("roundtrip not identical".into()),
                Err(e) => Err(format!("decode failed: {e}")),
            }
        });
    }

    #[test]
    fn decode_into_reuses_buffers_across_messages() {
        // A big message followed by a small one into the same SparseGrad:
        // the decode must fully reset length/contents (capacity persists).
        let big = sample_message(2048, 0.6, 90);
        let small = sample_message(64, 0.1, 91);
        let mut buf = Vec::new();
        let mut slot = SparseGrad::empty(0);
        encode(&big, &mut buf);
        decode_into(&buf, &mut slot).unwrap();
        assert_eq!(slot, big);
        let cap_before = slot.exact.capacity();
        encode(&small, &mut buf);
        decode_into(&buf, &mut slot).unwrap();
        assert_eq!(slot, small);
        assert!(slot.exact.capacity() >= cap_before, "capacity must be kept");
    }

    #[test]
    fn entropy_roundtrips_and_never_exceeds_raw_size() {
        for (d, rho) in [(4096usize, 0.01f32), (1024, 0.05), (128, 0.8), (64, 1.0)] {
            let sg = sample_message(d, rho, 80 + d as u64);
            let mut raw = Vec::new();
            let mut ent = Vec::new();
            encode_with(&sg, WireCodec::Raw, &mut raw);
            let enc = encode_with(&sg, WireCodec::Entropy, &mut ent);
            assert_eq!(ent.len(), encoded_len_with(&sg, WireCodec::Entropy));
            assert!(ent.len() <= raw.len(), "d={d} rho={rho}: {} > {}", ent.len(), raw.len());
            assert_eq!(decode(&ent).unwrap(), sg, "d={d} rho={rho} enc={enc:?}");
        }
    }

    #[test]
    fn entropy_rice_wins_on_sparse_sorted_indices() {
        // The motivating case: d ≫ nnz with near-uniform gaps — Rice-coded
        // deltas must beat both raw encodings outright.
        let sg = sample_message(1 << 16, 0.01, 90);
        assert!(sg.shared.len() > 32, "workload sanity");
        let mut buf = Vec::new();
        let enc = encode_with(&sg, WireCodec::Entropy, &mut buf);
        assert_eq!(enc, Encoding::IndexedRice);
        let raw_len = encoded_len_with(&sg, WireCodec::Raw);
        assert!(
            (buf.len() as f64) < 0.6 * raw_len as f64,
            "rice {} vs raw {raw_len}",
            buf.len()
        );
        assert_eq!(decode(&buf).unwrap(), sg);
    }

    #[test]
    fn entropy_dense_symbol_messages_match_raw_bytes() {
        // When DenseSymbols is cheapest the two codecs must emit identical
        // bytes — the 2-bit stream is packed the same way under both.
        let sg = sample_message(256, 0.9, 91);
        let mut raw = Vec::new();
        let mut ent = Vec::new();
        assert_eq!(encode_with(&sg, WireCodec::Raw, &mut raw), Encoding::DenseSymbols);
        assert_eq!(
            encode_with(&sg, WireCodec::Entropy, &mut ent),
            Encoding::DenseSymbols
        );
        assert_eq!(raw, ent);
    }

    #[test]
    fn rice_rejects_oversized_parameter() {
        let sg = sample_message(1 << 14, 0.02, 92);
        let mut buf = Vec::new();
        assert_eq!(encode_with(&sg, WireCodec::Entropy, &mut buf), Encoding::IndexedRice);
        for byte in [6usize, 7] {
            let mut bad = buf.clone();
            bad[byte] = 32;
            assert_eq!(decode(&bad), Err(WireError::BadRiceParam(32)));
            bad[byte] = 0xFF;
            assert_eq!(decode(&bad), Err(WireError::BadRiceParam(0xFF)));
        }
    }

    #[test]
    fn rice_rejects_gap_overflow_and_bad_padding() {
        // Hand-build a tiny rice message so every corruption is surgical:
        // d = 8, one shared survivor at index 2, k_b = 0.
        let mut sg = SparseGrad::empty(8);
        sg.shared.push((2, false));
        sg.shared_mag = 1.0;
        let mut buf = Vec::new();
        // Force the rice encoding by building it by hand (the encoder would
        // pick DenseSymbols at this size).
        buf.extend_from_slice(MAGIC);
        buf.push(VERSION);
        buf.push(Encoding::IndexedRice as u8);
        buf.push(0); // ka
        buf.push(0); // kb
        buf.extend_from_slice(&8u32.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes()); // na
        buf.extend_from_slice(&1u32.to_le_bytes()); // nb
        buf.extend_from_slice(&1.0f32.to_le_bytes());
        buf.push(0); // sign bitmap
        buf.push(0b011); // unary "110" LSB-first = gap 2, then zero padding
        assert_eq!(decode(&buf).unwrap(), sg);

        // Gap overflow past d: 8 unary ones + terminator encode gap 8, so
        // the accumulated index lands at 8 ≥ d.
        let mut bad = buf.clone();
        *bad.last_mut().unwrap() = 0xFF;
        bad.push(0x00);
        assert_eq!(
            decode(&bad),
            Err(WireError::IndexOutOfBounds { index: 8, d: 8 })
        );

        // Quotient overflow: a longer all-ones run exceeds d >> k and must
        // stop scanning instead of walking the stream.
        let mut bad = buf.clone();
        *bad.last_mut().unwrap() = 0xFF;
        bad.push(0xFF);
        assert_eq!(
            decode(&bad),
            Err(WireError::BadRiceStream("quotient overflow"))
        );

        // Non-canonical padding: flip a bit above the final codeword.
        let mut bad = buf.clone();
        *bad.last_mut().unwrap() = 0b1000_0011;
        assert_eq!(decode(&bad), Err(WireError::BadRiceStream("nonzero padding")));

        // Truncation below the provable minimum length.
        let mut bad = buf.clone();
        bad.pop();
        assert!(matches!(
            decode(&bad),
            Err(WireError::LengthMismatch { .. })
        ));

        // Trailing bytes beyond the codewords are non-canonical too.
        let mut bad = buf.clone();
        bad.push(0x00);
        assert!(matches!(
            decode(&bad),
            Err(WireError::LengthMismatch { .. })
        ));
    }

    #[test]
    fn property_roundtrip_random_messages() {
        crate::proptest_lite::run("wire roundtrip is exact", 64, |gen| {
            let d = gen.usize_in(1, 2000);
            let rho = gen.f32_in(0.01, 1.0);
            let g = gen.gradient_vec(d);
            let mut p = Vec::new();
            let pv = greedy_probs(&g, rho, 2, &mut p);
            let mut ra = RandArray::new(
                crate::rngkit::Xoshiro256pp::seed_from_u64(gen.u64()),
                1 << 14,
            );
            let sg = sample_sparse(&g, &p, pv.inv_lambda, &mut ra);
            let mut buf = Vec::new();
            encode(&sg, &mut buf);
            if buf.len() != encoded_len(&sg) {
                return Err(format!("encoded_len {} != actual {}", encoded_len(&sg), buf.len()));
            }
            match decode(&buf) {
                Ok(back) if back == sg => Ok(()),
                Ok(_) => Err("roundtrip not identical".into()),
                Err(e) => Err(format!("decode failed: {e}")),
            }
        });
    }
}
