//! All-Reduce over *encoded* sparsified-gradient messages — Algorithm 1
//! steps 6–8.
//!
//! The [`Aggregator`] consumes the actual wire bytes each worker produced
//! (round-tripping through [`crate::coding`] so the simulation exercises the
//! real codec), averages them into a dense gradient `v_t = (1/M) Σ_m
//! Q(g^m)`, and reports the per-round byte and simulated-time cost. When the
//! combined density is low it aggregates sparsely without materializing
//! per-worker dense vectors.

use super::network::NetworkModel;
use crate::coding;
use crate::sparsify::SparseGrad;

/// How the reduction is computed (numerically identical; different cost
/// accounting and memory behaviour).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceAlgo {
    /// Master decodes M messages and accumulates into one dense buffer.
    Naive,
    /// Sparse accumulation: survivors are scatter-added without any dense
    /// per-worker intermediate (wins when ρ·M ≪ 1).
    Sparse,
}

/// Result of one aggregation round.
#[derive(Debug, Clone)]
pub struct AggregateOutput {
    /// Total encoded bytes uploaded by workers this round.
    pub upload_bytes: u64,
    /// Bytes broadcast back (dense averaged gradient, or re-sparsified).
    pub broadcast_bytes: u64,
    /// Simulated wall time of the round under the aggregator's network model.
    pub sim_time_s: f64,
}

/// Synchronous All-Reduce master (also usable as a worker-side mirror since
/// the reduction is deterministic given the same messages). All scratch —
/// wire bytes, decoded messages, the dense reference buffer, and the
/// per-worker byte ledger — is reused across rounds, so a steady-state
/// [`Aggregator::reduce`] performs no heap allocation.
pub struct Aggregator {
    pub net: NetworkModel,
    pub algo: ReduceAlgo,
    /// Scratch for decode (reused across rounds).
    decode_buf: Vec<SparseGrad>,
    wire_buf: Vec<u8>,
    dense_scratch: Vec<f32>,
    worker_bytes: Vec<u64>,
}

impl Aggregator {
    pub fn new(net: NetworkModel, algo: ReduceAlgo) -> Self {
        Self {
            net,
            algo,
            decode_buf: Vec::new(),
            wire_buf: Vec::new(),
            dense_scratch: Vec::new(),
            worker_bytes: Vec::new(),
        }
    }

    /// Encode each worker's sparse gradient to bytes, "transmit", decode,
    /// and average into `out` (len d, zeroed by this call). Returns the cost
    /// accounting. This is the honest path used by integration tests; the
    /// figure drivers use [`Aggregator::reduce_decoded`] on pre-encoded
    /// messages when they already hold them.
    pub fn reduce(&mut self, grads: &[SparseGrad], out: &mut [f32]) -> AggregateOutput {
        let m = grads.len();
        assert!(m > 0, "no workers");
        let mut upload_bytes = 0u64;
        if self.decode_buf.len() < m {
            self.decode_buf.resize_with(m, || SparseGrad::empty(0));
        }
        for (sg, slot) in grads.iter().zip(self.decode_buf.iter_mut()) {
            coding::encode(sg, &mut self.wire_buf);
            upload_bytes += self.wire_buf.len() as u64;
            coding::decode_into(&self.wire_buf, slot).expect("self-encoded message");
        }
        let decoded = std::mem::take(&mut self.decode_buf);
        let res = self.reduce_decoded(&decoded[..m], upload_bytes, out);
        self.decode_buf = decoded;
        res
    }

    /// Average already-decoded messages into `out`.
    pub fn reduce_decoded(
        &mut self,
        grads: &[SparseGrad],
        upload_bytes: u64,
        out: &mut [f32],
    ) -> AggregateOutput {
        let m = grads.len();
        out.fill(0.0);
        let inv_m = 1.0 / m as f32;
        match self.algo {
            ReduceAlgo::Naive => {
                // Decode each worker to dense then axpy (reference path).
                self.dense_scratch.resize(out.len(), 0.0);
                let dense = &mut self.dense_scratch[..out.len()];
                for sg in grads {
                    dense.fill(0.0);
                    sg.add_into(1.0, dense);
                    crate::tensor::axpy(inv_m, dense, out);
                }
            }
            ReduceAlgo::Sparse => {
                for sg in grads {
                    sg.add_into(inv_m, out);
                }
            }
        }
        // Broadcast: dense averaged gradient (Algorithm 1 step 8). The
        // optional step-7 re-sparsification is applied by the coordinator
        // before calling this when enabled.
        let broadcast_bytes = (out.len() * 4) as u64;
        let per_worker = upload_bytes / m as u64;
        self.worker_bytes.clear();
        self.worker_bytes.extend((0..m).map(|i| {
            // Distribute the remainder deterministically.
            per_worker + if (i as u64) < upload_bytes % m as u64 { 1 } else { 0 }
        }));
        AggregateOutput {
            upload_bytes,
            broadcast_bytes,
            sim_time_s: self.net.round_time_s(&self.worker_bytes, broadcast_bytes),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngkit::RandArray;
    use crate::sparsify::{greedy_probs, sample_sparse};

    fn worker_grad(d: usize, seed: u64, rho: f32) -> SparseGrad {
        let mut rng = crate::rngkit::Xoshiro256pp::seed_from_u64(seed);
        let g: Vec<f32> = (0..d).map(|_| (rng.next_gaussian() * 0.4) as f32).collect();
        let mut p = Vec::new();
        let pv = greedy_probs(&g, rho, 2, &mut p);
        let mut ra = RandArray::from_seed(seed ^ 0xF00D, 1 << 16);
        sample_sparse(&g, &p, pv.inv_lambda, &mut ra)
    }

    #[test]
    fn naive_and_sparse_agree() {
        let d = 512;
        let grads: Vec<SparseGrad> = (0..4).map(|m| worker_grad(d, 100 + m, 0.2)).collect();
        let mut a = Aggregator::new(NetworkModel::datacenter_10g(), ReduceAlgo::Naive);
        let mut b = Aggregator::new(NetworkModel::datacenter_10g(), ReduceAlgo::Sparse);
        let mut out_a = vec![0.0; d];
        let mut out_b = vec![0.0; d];
        let ra = a.reduce(&grads, &mut out_a);
        let rb = b.reduce(&grads, &mut out_b);
        for i in 0..d {
            assert!((out_a[i] - out_b[i]).abs() < 1e-6, "coord {i}");
        }
        assert_eq!(ra.upload_bytes, rb.upload_bytes);
    }

    #[test]
    fn reduce_is_mean_of_decodes() {
        let d = 128;
        let grads: Vec<SparseGrad> = (0..3).map(|m| worker_grad(d, 200 + m, 0.5)).collect();
        let mut agg = Aggregator::new(NetworkModel::datacenter_10g(), ReduceAlgo::Sparse);
        let mut out = vec![0.0; d];
        agg.reduce(&grads, &mut out);
        let mut expect = vec![0.0f64; d];
        for sg in &grads {
            for (i, v) in sg.to_dense().into_iter().enumerate() {
                expect[i] += v as f64 / 3.0;
            }
        }
        for i in 0..d {
            assert!((out[i] as f64 - expect[i]).abs() < 1e-6, "coord {i}");
        }
    }

    #[test]
    fn cost_accounting_positive_and_scaling() {
        let d = 2048;
        let sparse: Vec<SparseGrad> = (0..4).map(|m| worker_grad(d, 300 + m, 0.02)).collect();
        let dense: Vec<SparseGrad> = (0..4).map(|m| worker_grad(d, 300 + m, 1.0)).collect();
        let mut agg = Aggregator::new(NetworkModel::commodity_1g(), ReduceAlgo::Sparse);
        let mut out = vec![0.0; d];
        let rs = agg.reduce(&sparse, &mut out);
        let rd = agg.reduce(&dense, &mut out);
        assert!(rs.upload_bytes * 4 < rd.upload_bytes, "sparsification should shrink uploads");
        assert!(rs.sim_time_s < rd.sim_time_s);
        assert_eq!(rs.broadcast_bytes, (d * 4) as u64);
    }

    #[test]
    fn single_worker_identity() {
        let d = 64;
        let g = worker_grad(d, 400, 0.9);
        let mut agg = Aggregator::new(NetworkModel::datacenter_10g(), ReduceAlgo::Sparse);
        let mut out = vec![0.0; d];
        agg.reduce(std::slice::from_ref(&g), &mut out);
        let dense = g.to_dense();
        for i in 0..d {
            assert!((out[i] - dense[i]).abs() < 1e-7);
        }
    }
}
