//! All-Reduce over *encoded* sparsified-gradient messages — Algorithm 1
//! steps 6–8.
//!
//! The [`Aggregator`] consumes the actual wire bytes each worker produced
//! (round-tripping through [`crate::coding`] so the simulation exercises the
//! real codec), averages them into a dense gradient `v_t = (1/M) Σ_m
//! Q(g^m)`, and reports the per-round byte and simulated-time cost. When the
//! combined density is low it aggregates sparsely without materializing
//! per-worker dense vectors.

use super::network::NetworkModel;
use crate::coding;
use crate::sparsify::SparseGrad;

/// How the reduction is computed (numerically identical; different cost
/// accounting and memory behaviour).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceAlgo {
    /// Master decodes M messages and accumulates into one dense buffer.
    Naive,
    /// Sparse accumulation: survivors are scatter-added without any dense
    /// per-worker intermediate (wins when ρ·M ≪ 1).
    Sparse,
}

/// Typed failure of an aggregation round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceError {
    /// [`Aggregator::reduce`] was called with zero worker messages; there is
    /// nothing to average and `1/M` is undefined.
    EmptyWorkers,
}

impl std::fmt::Display for ReduceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReduceError::EmptyWorkers => write!(f, "reduce called with no worker gradients"),
        }
    }
}

impl std::error::Error for ReduceError {}

/// Result of one aggregation round.
#[derive(Debug, Clone)]
pub struct AggregateOutput {
    /// Total encoded bytes uploaded by workers this round.
    pub upload_bytes: u64,
    /// Bytes broadcast back (dense averaged gradient, or re-sparsified).
    pub broadcast_bytes: u64,
    /// Simulated wall time of the round under the aggregator's network model.
    pub sim_time_s: f64,
}

/// Synchronous All-Reduce master (also usable as a worker-side mirror since
/// the reduction is deterministic given the same messages). All scratch —
/// wire bytes, decoded messages, the dense reference buffer, and the
/// per-worker byte ledger — is reused across rounds, so a steady-state
/// [`Aggregator::reduce`] performs no heap allocation.
pub struct Aggregator {
    pub net: NetworkModel,
    pub algo: ReduceAlgo,
    /// Scratch for decode (reused across rounds).
    decode_buf: Vec<SparseGrad>,
    wire_buf: Vec<u8>,
    dense_scratch: Vec<f32>,
    worker_bytes: Vec<u64>,
}

impl Aggregator {
    pub fn new(net: NetworkModel, algo: ReduceAlgo) -> Self {
        Self {
            net,
            algo,
            decode_buf: Vec::new(),
            wire_buf: Vec::new(),
            dense_scratch: Vec::new(),
            worker_bytes: Vec::new(),
        }
    }

    /// Encode each worker's sparse gradient to bytes, "transmit", decode,
    /// and average into `out` (len d, zeroed by this call). Returns the cost
    /// accounting. This is the honest path used by integration tests; the
    /// figure drivers use [`Aggregator::reduce_decoded`] on pre-encoded
    /// messages when they already hold them.
    pub fn reduce(
        &mut self,
        grads: &[SparseGrad],
        out: &mut [f32],
    ) -> Result<AggregateOutput, ReduceError> {
        let m = grads.len();
        if m == 0 {
            return Err(ReduceError::EmptyWorkers);
        }
        // Bound scratch to the current worker count: shrinking drops the
        // excess decoded messages (and their index/value heaps) instead of
        // pinning the high-water mark forever.
        if self.decode_buf.len() != m {
            self.decode_buf.resize_with(m, || SparseGrad::empty(0));
        }
        let mut per_worker = std::mem::take(&mut self.worker_bytes);
        per_worker.clear();
        for (sg, slot) in grads.iter().zip(self.decode_buf.iter_mut()) {
            coding::encode(sg, &mut self.wire_buf);
            per_worker.push(self.wire_buf.len() as u64);
            coding::decode_into(&self.wire_buf, slot).expect("self-encoded message");
        }
        let decoded = std::mem::take(&mut self.decode_buf);
        let res = self.reduce_decoded(&decoded[..m], &per_worker, out);
        self.decode_buf = decoded;
        self.worker_bytes = per_worker;
        Ok(res)
    }

    /// Average already-decoded messages into `out`. `worker_bytes[m]` is the
    /// measured encoded length of worker `m`'s message — the real sizes, so
    /// heterogeneous uploads cost what they actually cost under the network
    /// model (a uniform split would hide the straggler the ring max-chunk
    /// term keys on).
    pub fn reduce_decoded(
        &mut self,
        grads: &[SparseGrad],
        worker_bytes: &[u64],
        out: &mut [f32],
    ) -> AggregateOutput {
        debug_assert_eq!(grads.len(), worker_bytes.len());
        let m = grads.len();
        out.fill(0.0);
        let inv_m = 1.0 / m as f32;
        match self.algo {
            ReduceAlgo::Naive => {
                // Decode each worker to dense then axpy (reference path).
                self.dense_scratch.resize(out.len(), 0.0);
                let dense = &mut self.dense_scratch[..out.len()];
                for sg in grads {
                    dense.fill(0.0);
                    sg.add_into(1.0, dense);
                    crate::tensor::axpy(inv_m, dense, out);
                }
            }
            ReduceAlgo::Sparse => {
                for sg in grads {
                    sg.add_into(inv_m, out);
                }
            }
        }
        // Broadcast: dense averaged gradient (Algorithm 1 step 8). The
        // optional step-7 re-sparsification is applied by the coordinator
        // before calling this when enabled.
        let broadcast_bytes = (out.len() * 4) as u64;
        AggregateOutput {
            upload_bytes: worker_bytes.iter().sum(),
            broadcast_bytes,
            sim_time_s: self.net.round_time_s(worker_bytes, broadcast_bytes),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Topology;
    use crate::rngkit::RandArray;
    use crate::sparsify::{greedy_probs, sample_sparse};

    fn worker_grad(d: usize, seed: u64, rho: f32) -> SparseGrad {
        let mut rng = crate::rngkit::Xoshiro256pp::seed_from_u64(seed);
        let g: Vec<f32> = (0..d).map(|_| (rng.next_gaussian() * 0.4) as f32).collect();
        let mut p = Vec::new();
        let pv = greedy_probs(&g, rho, 2, &mut p);
        let mut ra = RandArray::from_seed(seed ^ 0xF00D, 1 << 16);
        sample_sparse(&g, &p, pv.inv_lambda, &mut ra)
    }

    #[test]
    fn naive_and_sparse_agree() {
        let d = 512;
        let grads: Vec<SparseGrad> = (0..4).map(|m| worker_grad(d, 100 + m, 0.2)).collect();
        let mut a = Aggregator::new(NetworkModel::datacenter_10g(), ReduceAlgo::Naive);
        let mut b = Aggregator::new(NetworkModel::datacenter_10g(), ReduceAlgo::Sparse);
        let mut out_a = vec![0.0; d];
        let mut out_b = vec![0.0; d];
        let ra = a.reduce(&grads, &mut out_a).unwrap();
        let rb = b.reduce(&grads, &mut out_b).unwrap();
        for i in 0..d {
            assert!((out_a[i] - out_b[i]).abs() < 1e-6, "coord {i}");
        }
        assert_eq!(ra.upload_bytes, rb.upload_bytes);
    }

    #[test]
    fn reduce_is_mean_of_decodes() {
        let d = 128;
        let grads: Vec<SparseGrad> = (0..3).map(|m| worker_grad(d, 200 + m, 0.5)).collect();
        let mut agg = Aggregator::new(NetworkModel::datacenter_10g(), ReduceAlgo::Sparse);
        let mut out = vec![0.0; d];
        agg.reduce(&grads, &mut out).unwrap();
        let mut expect = vec![0.0f64; d];
        for sg in &grads {
            for (i, v) in sg.to_dense().into_iter().enumerate() {
                expect[i] += v as f64 / 3.0;
            }
        }
        for i in 0..d {
            assert!((out[i] as f64 - expect[i]).abs() < 1e-6, "coord {i}");
        }
    }

    #[test]
    fn cost_accounting_positive_and_scaling() {
        let d = 2048;
        let sparse: Vec<SparseGrad> = (0..4).map(|m| worker_grad(d, 300 + m, 0.02)).collect();
        let dense: Vec<SparseGrad> = (0..4).map(|m| worker_grad(d, 300 + m, 1.0)).collect();
        let mut agg = Aggregator::new(NetworkModel::commodity_1g(), ReduceAlgo::Sparse);
        let mut out = vec![0.0; d];
        let rs = agg.reduce(&sparse, &mut out).unwrap();
        let rd = agg.reduce(&dense, &mut out).unwrap();
        assert!(rs.upload_bytes * 4 < rd.upload_bytes, "sparsification should shrink uploads");
        assert!(rs.sim_time_s < rd.sim_time_s);
        assert_eq!(rs.broadcast_bytes, (d * 4) as u64);
    }

    #[test]
    fn single_worker_identity() {
        let d = 64;
        let g = worker_grad(d, 400, 0.9);
        let mut agg = Aggregator::new(NetworkModel::datacenter_10g(), ReduceAlgo::Sparse);
        let mut out = vec![0.0; d];
        agg.reduce(std::slice::from_ref(&g), &mut out).unwrap();
        let dense = g.to_dense();
        for i in 0..d {
            assert!((out[i] - dense[i]).abs() < 1e-7);
        }
    }

    #[test]
    fn empty_worker_set_is_typed_error_not_panic() {
        let mut agg = Aggregator::new(NetworkModel::datacenter_10g(), ReduceAlgo::Sparse);
        let mut out = vec![0.0; 16];
        assert_eq!(agg.reduce(&[], &mut out), Err(ReduceError::EmptyWorkers));
    }

    #[test]
    fn decode_scratch_tracks_worker_count() {
        // Regression: `decode_buf` only ever grew, so one wide round pinned
        // the high-water mark of decoded-message heap forever.
        let d = 256;
        let wide: Vec<SparseGrad> = (0..8).map(|m| worker_grad(d, 500 + m, 0.3)).collect();
        let narrow: Vec<SparseGrad> = (0..2).map(|m| worker_grad(d, 600 + m, 0.3)).collect();
        let mut agg = Aggregator::new(NetworkModel::datacenter_10g(), ReduceAlgo::Sparse);
        let mut out = vec![0.0; d];
        agg.reduce(&wide, &mut out).unwrap();
        assert_eq!(agg.decode_buf.len(), 8);
        agg.reduce(&narrow, &mut out).unwrap();
        assert_eq!(agg.decode_buf.len(), 2, "scratch must shrink with m");
    }

    #[test]
    fn heterogeneous_uploads_use_measured_per_worker_bytes() {
        // Regression: `reduce_decoded` used to spread the total uniformly,
        // which hides the straggler the ring max-chunk term keys on.
        let d = 4096;
        let mut grads: Vec<SparseGrad> = (0..3).map(|m| worker_grad(d, 700 + m, 0.01)).collect();
        grads.push(worker_grad(d, 703, 1.0)); // one near-dense straggler
        let net = NetworkModel {
            topology: Topology::Ring,
            ..NetworkModel::commodity_1g()
        };
        let mut agg = Aggregator::new(net, ReduceAlgo::Sparse);
        let mut out = vec![0.0; d];
        let res = agg.reduce(&grads, &mut out).unwrap();
        // The fabricated-uniform accounting would have charged the mean
        // upload; the honest ring time keys on the max.
        let uniform = vec![res.upload_bytes / 4; 4];
        let fabricated = net.round_time_s(&uniform, res.broadcast_bytes);
        assert!(
            res.sim_time_s > fabricated,
            "measured {} !> uniform-fabricated {}",
            res.sim_time_s,
            fabricated
        );
    }
}
