//! Sparse merge kernels for collective reductions.
//!
//! A ring reduce-scatter sums *messages*, not dense vectors: each hop merges
//! two [`SparseGrad`]s by index union, summing magnitudes where indices
//! collide, and (optionally) re-sparsifies the partial sum so per-hop message
//! size stays bounded. The kernels here are the arithmetic core of
//! [`crate::collective`]; they are deterministic — identical inputs produce
//! bitwise-identical outputs regardless of backend or thread count — because
//! the ring schedule pins the merge order and these kernels never iterate in
//! hash or address order.

use crate::sparsify::SparseGrad;

/// Iterator over a [`SparseGrad`]'s decoded `(index, value)` entries in
/// ascending index order, interleaving the exact (`Q_A`) and shared-magnitude
/// (`Q_B`) streams (each is ascending and they are disjoint).
pub struct Entries<'a> {
    exact: std::slice::Iter<'a, (u32, f32)>,
    shared: std::slice::Iter<'a, (u32, bool)>,
    mag: f32,
    next_exact: Option<(u32, f32)>,
    next_shared: Option<(u32, bool)>,
}

impl<'a> Entries<'a> {
    pub fn new(sg: &'a SparseGrad) -> Self {
        let mut exact = sg.exact.iter();
        let mut shared = sg.shared.iter();
        let next_exact = exact.next().copied();
        let next_shared = shared.next().copied();
        Self {
            exact,
            shared,
            mag: sg.shared_mag,
            next_exact,
            next_shared,
        }
    }
}

impl Iterator for Entries<'_> {
    type Item = (u32, f32);

    fn next(&mut self) -> Option<(u32, f32)> {
        match (self.next_exact, self.next_shared) {
            (None, None) => None,
            (Some((i, v)), None) => {
                self.next_exact = self.exact.next().copied();
                Some((i, v))
            }
            (None, Some((i, neg))) => {
                self.next_shared = self.shared.next().copied();
                Some((i, if neg { -self.mag } else { self.mag }))
            }
            (Some((ie, v)), Some((is, neg))) => {
                if ie < is {
                    self.next_exact = self.exact.next().copied();
                    Some((ie, v))
                } else {
                    self.next_shared = self.shared.next().copied();
                    Some((is, if neg { -self.mag } else { self.mag }))
                }
            }
        }
    }
}

/// `out = a + b` as an exact-valued sparse message: index union, colliding
/// magnitudes summed (`a`'s contribution added first — the caller's hop order
/// pins float associativity). `out` is reset to dimension `a.d`; the result
/// carries everything in `exact` because a sum of two messages no longer has
/// a common shared magnitude.
pub fn merge_sum(a: &SparseGrad, b: &SparseGrad, out: &mut SparseGrad) {
    assert_eq!(a.d, b.d, "dimension mismatch in merge_sum");
    out.reset(a.d as usize);
    let mut ita = Entries::new(a).peekable();
    let mut itb = Entries::new(b).peekable();
    loop {
        match (ita.peek().copied(), itb.peek().copied()) {
            (None, None) => break,
            (Some((i, v)), None) => {
                out.exact.push((i, v));
                ita.next();
            }
            (None, Some((i, v))) => {
                out.exact.push((i, v));
                itb.next();
            }
            (Some((ia, va)), Some((ib, vb))) => {
                if ia < ib {
                    out.exact.push((ia, va));
                    ita.next();
                } else if ib < ia {
                    out.exact.push((ib, vb));
                    itb.next();
                } else {
                    out.exact.push((ia, va + vb));
                    ita.next();
                    itb.next();
                }
            }
        }
    }
}

/// Rewrite `sg` so every entry lives in `exact` (ascending index) and the
/// shared stream is empty. Partial sums lose the common-magnitude structure
/// after the first merge anyway; normalizing first keeps the merge kernels
/// single-stream.
pub fn promote_to_exact(sg: &mut SparseGrad) {
    if sg.shared.is_empty() {
        sg.shared_mag = 0.0;
        return;
    }
    let mag = sg.shared_mag;
    let shared = std::mem::take(&mut sg.shared);
    sg.exact
        .extend(shared.iter().map(|&(i, neg)| (i, if neg { -mag } else { mag })));
    // Exact and shared index sets are disjoint and each ascending; one sort
    // restores global ascending order deterministically.
    sg.exact.sort_unstable_by_key(|&(i, _)| i);
    sg.shared = shared; // keep the (now empty, cleared below) allocation
    sg.shared.clear();
    sg.shared_mag = 0.0;
}

/// Keep the `budget` largest-magnitude entries of `sg` (deterministic
/// tie-break: larger |value| first via IEEE total order, then lower index)
/// and append every dropped `(index, value)` to `dropped` so the caller can
/// fold the lost mass into an error-feedback residual. No-op when the
/// message already fits.
pub fn resparsify_top(sg: &mut SparseGrad, budget: usize, dropped: &mut Vec<(u32, f32)>) {
    promote_to_exact(sg);
    if sg.exact.len() <= budget {
        return;
    }
    sg.exact.sort_unstable_by(|a, b| {
        b.1.abs()
            .total_cmp(&a.1.abs())
            .then_with(|| a.0.cmp(&b.0))
    });
    dropped.extend(sg.exact.drain(budget..));
    sg.exact.sort_unstable_by_key(|&(i, _)| i);
}

/// Concatenate per-layer messages into one flat message over the summed
/// dimension `Σ dims[l]`, with layer `l`'s coordinates shifted by the prefix
/// offset. Everything is promoted to exact values.
pub fn flatten_concat(layers: &[&SparseGrad], out: &mut SparseGrad) {
    let total: usize = layers.iter().map(|sg| sg.d as usize).sum();
    out.reset(total);
    let mut offset = 0u32;
    for sg in layers {
        out.exact.extend(Entries::new(sg).map(|(i, v)| (offset + i, v)));
        offset += sg.d;
    }
}

/// Scatter a flat concatenated message back onto per-layer dense buffers:
/// entry `(i, v)` lands in the layer whose offset range contains `i`, scaled
/// by `alpha`. Inverse of [`flatten_concat`]'s coordinate shift.
pub fn scatter_concat(sg: &SparseGrad, alpha: f32, layers: &mut [&mut [f32]]) {
    let total: usize = layers.iter().map(|l| l.len()).sum();
    assert_eq!(total, sg.d as usize, "layer dims do not cover the flat message");
    let mut layer = 0usize;
    let mut offset = 0usize;
    for (i, v) in Entries::new(sg) {
        let i = i as usize;
        // Entries ascend, so the layer cursor only ever moves forward.
        while i >= offset + layers[layer].len() {
            offset += layers[layer].len();
            layer += 1;
        }
        layers[layer][i - offset] += alpha * v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sg(d: u32, exact: &[(u32, f32)], shared: &[(u32, bool)], mag: f32) -> SparseGrad {
        SparseGrad {
            d,
            exact: exact.to_vec(),
            shared: shared.to_vec(),
            shared_mag: mag,
        }
    }

    #[test]
    fn entries_interleave_both_streams_ascending() {
        let g = sg(10, &[(1, 2.0), (5, -1.0)], &[(0, true), (3, false)], 0.5);
        let got: Vec<(u32, f32)> = Entries::new(&g).collect();
        assert_eq!(got, vec![(0, -0.5), (1, 2.0), (3, 0.5), (5, -1.0)]);
    }

    #[test]
    fn merge_sum_matches_dense_sum() {
        let a = sg(8, &[(0, 1.0), (4, 2.0)], &[(2, false)], 0.25);
        let b = sg(8, &[(2, 3.0), (4, -1.5)], &[(7, true)], 0.75);
        let mut out = SparseGrad::empty(0);
        merge_sum(&a, &b, &mut out);
        let mut expect = a.to_dense();
        for (i, v) in b.to_dense().into_iter().enumerate() {
            expect[i] += v;
        }
        assert_eq!(out.to_dense(), expect);
        assert!(out.shared.is_empty());
        assert!(out.exact.windows(2).all(|w| w[0].0 < w[1].0));
    }

    #[test]
    fn resparsify_keeps_top_budget_and_reports_dropped() {
        let mut g = sg(8, &[(0, 0.1), (3, -5.0), (6, 2.0)], &[(1, false)], 3.0);
        let mut dropped = Vec::new();
        resparsify_top(&mut g, 2, &mut dropped);
        assert_eq!(g.exact, vec![(1, 3.0), (3, -5.0)]);
        // Dropped mass is reported so the caller can fold it into a residual.
        let mut d2 = dropped.clone();
        d2.sort_unstable_by_key(|&(i, _)| i);
        assert_eq!(d2, vec![(0, 0.1), (6, 2.0)]);
    }

    #[test]
    fn resparsify_tie_breaks_by_lower_index() {
        let mut g = sg(4, &[(0, 1.0), (1, -1.0), (2, 1.0)], &[], 0.0);
        let mut dropped = Vec::new();
        resparsify_top(&mut g, 2, &mut dropped);
        assert_eq!(g.exact, vec![(0, 1.0), (1, -1.0)]);
        assert_eq!(dropped, vec![(2, 1.0)]);
    }

    #[test]
    fn flatten_then_scatter_round_trips() {
        let a = sg(4, &[(1, 2.0)], &[(3, true)], 0.5);
        let b = sg(6, &[(0, -1.0), (5, 4.0)], &[], 0.0);
        let mut flat = SparseGrad::empty(0);
        flatten_concat(&[&a, &b], &mut flat);
        assert_eq!(flat.d, 10);
        let mut la = vec![0.0f32; 4];
        let mut lb = vec![0.0f32; 6];
        scatter_concat(&flat, 1.0, &mut [la.as_mut_slice(), lb.as_mut_slice()]);
        assert_eq!(la, a.to_dense());
        assert_eq!(lb, b.to_dense());
    }
}
