//! Simulated cluster communication layer.
//!
//! The paper's convex experiments simulate M machines (§5.1: "We simulated
//! with M=4 machines, where one machine is both a worker and the master").
//! This module makes the simulation *honest*: workers produce real encoded
//! byte messages ([`crate::coding`]), the [`Aggregator`] combines them into
//! an averaged dense gradient exactly as Algorithm 1 steps 6–8 describe, and
//! a [`NetworkModel`] (α-β latency/bandwidth cost model) translates the bytes
//! that crossed the simulated wire into simulated wall time so figure drivers
//! can report communication-bound speedups.

mod allreduce;
pub mod merge;
mod network;

pub use allreduce::{AggregateOutput, Aggregator, ReduceAlgo, ReduceError};
pub use network::{NetworkModel, Topology};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reexports_compile() {
        let net = NetworkModel::datacenter_10g();
        assert!(net.message_time_s(1500) > 0.0);
        let _ = ReduceAlgo::Naive;
        let _ = Topology::Star;
    }
}
