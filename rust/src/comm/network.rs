//! α-β network cost model: transmitting an `n`-byte message costs
//! `α + n/β` seconds (latency + inverse bandwidth). Used to convert the byte
//! ledger of a training run into *simulated* communication wall time — the
//! substitution for the authors' real 4-machine cluster (DESIGN.md
//! §Substitutions).

/// Physical topology of the simulated cluster; affects how many sequential
/// message times one synchronization round costs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Parameter-server star: the master receives M−1 messages and
    /// broadcasts one (the paper's Algorithm 1 with a master node).
    Star,
    /// Ring all-reduce: 2(M−1) phases, each carrying ~1/M of the payload.
    Ring,
}

/// α-β cost model for a homogeneous cluster link.
#[derive(Clone, Copy, Debug)]
pub struct NetworkModel {
    /// Per-message latency α in seconds.
    pub alpha_s: f64,
    /// Bandwidth β in bytes/second.
    pub beta_bytes_per_s: f64,
    pub topology: Topology,
}

impl NetworkModel {
    /// 10 GbE datacenter defaults: 50 µs latency, 1.25 GB/s.
    pub fn datacenter_10g() -> Self {
        Self {
            alpha_s: 50e-6,
            beta_bytes_per_s: 1.25e9,
            topology: Topology::Star,
        }
    }

    /// 1 GbE commodity cluster: 200 µs latency, 125 MB/s — closest to the
    /// paper's 2017-era testbed assumption.
    pub fn commodity_1g() -> Self {
        Self {
            alpha_s: 200e-6,
            beta_bytes_per_s: 125e6,
            topology: Topology::Star,
        }
    }

    /// Time for a single point-to-point message of `bytes`.
    pub fn message_time_s(&self, bytes: u64) -> f64 {
        self.alpha_s + bytes as f64 / self.beta_bytes_per_s
    }

    /// Simulated time of one synchronization round of Algorithm 1 steps 6–8:
    /// `worker_bytes[m]` is what worker `m` uploads; `broadcast_bytes` is the
    /// averaged gradient (or weight) pushed back to everyone.
    pub fn round_time_s(&self, worker_bytes: &[u64], broadcast_bytes: u64) -> f64 {
        match self.topology {
            Topology::Star => {
                // Uploads are serialized at the master's NIC (conservative,
                // like the paper's single aggregating machine), broadcast
                // counted once (switch multicast assumption).
                let upload: f64 = worker_bytes
                    .iter()
                    .map(|&b| self.message_time_s(b))
                    .sum();
                upload + self.message_time_s(broadcast_bytes)
            }
            Topology::Ring => {
                // (M−1) reduce-scatter phases each carrying the max worker
                // chunk of ~1/M, then (M−1) all-gather phases each carrying
                // ~1/M of the broadcast payload. Every phase pays the α
                // latency floor.
                let m = worker_bytes.len().max(1) as f64;
                let max_bytes = worker_bytes.iter().copied().max().unwrap_or(0) as f64;
                let scatter = self.alpha_s + (max_bytes / m) / self.beta_bytes_per_s;
                let gather = self.alpha_s + (broadcast_bytes as f64 / m) / self.beta_bytes_per_s;
                (m - 1.0) * (scatter + gather)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn message_time_has_latency_floor() {
        let net = NetworkModel::datacenter_10g();
        assert!(net.message_time_s(0) >= 50e-6);
        // 1.25 GB at 1.25 GB/s ≈ 1 s.
        let t = net.message_time_s(1_250_000_000);
        assert!((t - 1.0).abs() < 0.01, "{t}");
    }

    #[test]
    fn star_round_scales_with_workers() {
        let net = NetworkModel::commodity_1g();
        let t2 = net.round_time_s(&[1000, 1000], 1000);
        let t4 = net.round_time_s(&[1000; 4], 1000);
        assert!(t4 > t2);
    }

    #[test]
    fn smaller_messages_cost_less() {
        let net = NetworkModel::commodity_1g();
        // Bandwidth-bound regime (MB-scale messages): a 20× smaller
        // sparsified message wins by ≈20×. (At KB scale the α latency floor
        // dominates and compression buys little — that regime is asserted
        // separately below.)
        let dense = net.round_time_s(&[10_000_000; 4], 10_000_000);
        let sparse = net.round_time_s(&[500_000; 4], 500_000);
        assert!(sparse < dense / 5.0, "sparse {sparse} vs dense {dense}");
        // Latency-bound regime: both pay ≈ the same α floor.
        let tiny_dense = net.round_time_s(&[4000; 4], 4000);
        let tiny_sparse = net.round_time_s(&[200; 4], 200);
        assert!(tiny_sparse > tiny_dense / 3.0);
    }

    #[test]
    fn ring_round_strictly_increases_with_broadcast_payload() {
        // Regression: the Ring arm used to drop `broadcast_bytes` entirely,
        // making ring-vs-star comparisons dishonest (the all-gather phases
        // were free). Ring time must be strictly monotone in the broadcast
        // payload.
        let net = NetworkModel {
            topology: Topology::Ring,
            ..NetworkModel::datacenter_10g()
        };
        let uploads = vec![1_000_000u64; 8];
        let mut prev = net.round_time_s(&uploads, 0);
        for bcast in [1_000u64, 1_000_000, 100_000_000] {
            let t = net.round_time_s(&uploads, bcast);
            assert!(t > prev, "broadcast {bcast}: {t} !> {prev}");
            prev = t;
        }
    }

    #[test]
    fn ring_beats_star_for_large_messages_many_workers() {
        let mut net = NetworkModel::datacenter_10g();
        let payload = vec![10_000_000u64; 16];
        let star = net.round_time_s(&payload, 10_000_000);
        net.topology = Topology::Ring;
        let ring = net.round_time_s(&payload, 10_000_000);
        assert!(ring < star, "ring {ring} vs star {star}");
    }
}
