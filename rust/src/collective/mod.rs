//! Sparse ring collectives over the [`crate::transport`] trait.
//!
//! The parameter-server topology every coordinator started from has a cost
//! asymmetry the paper's §4 cost model makes explicit: the leader receives
//! `M` sparse messages per round, so its ingress grows linearly with the
//! worker count while every worker pays a constant. A ring
//! reduce-scatter / all-gather removes the hot spot — each of the `2(M−1)`
//! phases moves roughly `1/M` of the payload over every link, so per-node
//! traffic stops growing with `M`.
//!
//! Dense rings are textbook; *sparse* rings are not, because a hop no longer
//! sums two aligned buffers — it merges two index sets. This module provides
//! the two designs the literature converged on:
//!
//! * [`RingReducer::reduce`] — index-carrying hops. Each hop payload is a
//!   one-message `WireBatch` ([`crate::coding::encode_batch`]); every hop
//!   merges the incoming message into the local chunk accumulator by index
//!   union ([`crate::comm::merge::merge_sum`]) and, under a per-hop `budget`,
//!   re-sparsifies the partial sum ([`crate::comm::merge::resparsify_top`]),
//!   folding the dropped mass into an error-feedback residual
//!   ([`crate::feedback::FeedbackState`]) so nothing is silently lost.
//!   Without a budget the reduction is exact but hop messages grow as index
//!   sets union (up to `m·k` entries) — budget `⌈2ρD/m⌉` restores the ring's
//!   per-node advantage at the cost of a top-k bias the residual repairs.
//! * [`RingReducer::reduce_aligned`] — index-free hops (ARC-style aligned
//!   sparsity). Every rank sketches its local message into a shared-seed
//!   count sketch, the sketches are ring-all-gathered and summed in rank
//!   order, and each rank independently selects the same top-`k` index set
//!   from the summed sketch (the estimate, tie-break, and sort are all
//!   deterministic). The reduction then runs over the `k` *positions* —
//!   raw `f32` little-endian payloads, no indices on the wire — and the
//!   selected coordinates carry their **exact** sums (the sketch only picks
//!   *which* coordinates travel). Unselected local mass folds into the
//!   residual.
//!
//! Both paths are bitwise deterministic across backends and thread counts:
//! the ring schedule pins which rank's contribution is added when (chunk
//! `c`'s sum left-folds in ring order starting at rank `c`), hop payloads
//! round-trip losslessly through the wire codec, and no kernel iterates in
//! hash or address order.
//!
//! **Deadlock note.** Each phase is "every rank sends to its right
//! neighbour, then receives from its left". That is safe on
//! [`InProcTransport`](crate::transport::InProcTransport) (unbounded
//! channels) and on TCP whenever a hop payload fits the kernel socket
//! buffers — which budgeted hops do by construction. Callers pushing
//! unbudgeted multi-megabyte hops over TCP should size `budget` instead of
//! relying on socket buffering.

use crate::coding::{self, WireCodec};
use crate::comm::merge;
use crate::feedback::FeedbackState;
use crate::sparsify::SparseGrad;
use crate::transport::frame::{self, Hello, MsgView};
use crate::transport::{Connection, LinkCounters, Listener, Transport, TransportError};

/// Reduce-scatter hop carrying a `WireBatch` sparse chunk.
pub const PHASE_REDUCE_SCATTER: u8 = 0;
/// All-gather hop forwarding a finalized `WireBatch` sparse chunk.
pub const PHASE_ALL_GATHER: u8 = 1;
/// Aligned mode: ring all-gather of raw `f32` count-sketch rows.
pub const PHASE_SKETCH: u8 = 2;
/// Aligned mode: reduce-scatter of raw `f32` values at the agreed indices.
pub const PHASE_VALUES_RS: u8 = 3;
/// Aligned mode: all-gather of the reduced raw `f32` values.
pub const PHASE_VALUES_AG: u8 = 4;

/// Coordinate range `[lo, hi)` of chunk `c` when dimension `d` is split into
/// `m` near-equal contiguous chunks. Exhaustive over `c = 0..m`: chunk
/// bounds tile `[0, d)` exactly, and widths differ by at most one.
pub fn chunk_bounds(d: u32, m: u32, c: u32) -> (u32, u32) {
    debug_assert!(c < m);
    let lo = (c as u64 * d as u64 / m as u64) as u32;
    let hi = ((c as u64 + 1) * d as u64 / m as u64) as u32;
    (lo, hi)
}

/// One rank's two ring links: `left` is the accepted connection from rank
/// `(rank + peers − 1) mod peers`, `right` the outgoing connection to rank
/// `(rank + 1) mod peers`. Built by [`form_ring_local`] (all ranks in one
/// process) or [`connect_ring`] (one rank of a distributed ring).
pub struct RingPeer {
    rank: u32,
    peers: u32,
    left: Box<dyn Connection>,
    right: Box<dyn Connection>,
}

impl RingPeer {
    pub fn rank(&self) -> u32 {
        self.rank
    }

    pub fn peers(&self) -> u32 {
        self.peers
    }

    /// Counter handle for the outgoing (right) link — hop bytes leave here.
    pub fn right_counters(&self) -> LinkCounters {
        self.right.counters()
    }

    /// Counter handle for the incoming (left) link.
    pub fn left_counters(&self) -> LinkCounters {
        self.left.counters()
    }
}

fn validate_neighbour(hello: &Hello, expect_rank: u32, codec: WireCodec) -> Result<(), TransportError> {
    let ours = codec.index() as u8;
    if hello.codec != ours {
        return Err(TransportError::CodecMismatch {
            ours,
            theirs: hello.codec,
        });
    }
    if hello.worker_id != expect_rank {
        return Err(TransportError::BadHandshake("ring neighbour rank mismatch"));
    }
    Ok(())
}

/// Form a full `m`-rank ring inside one process (the cluster coordinator and
/// the tests): bind all `m` listeners first, connect every rank to its right
/// neighbour's listener (safe single-threaded — listeners queue the connect
/// in their backlog before any accept), then accept every left neighbour,
/// validating that it announces the expected rank and wire codec.
///
/// `bind_addrs[r]` is rank `r`'s listen address (`"127.0.0.1:0"` for TCP,
/// any per-run-unique name for in-proc). Returns one [`RingPeer`] per rank,
/// indexed by rank. `m == 1` forms a self-loop; [`RingReducer`] never
/// touches the links in that case.
pub fn form_ring_local(
    transport: &dyn Transport,
    m: usize,
    codec: WireCodec,
    bind_addrs: &[String],
) -> Result<Vec<RingPeer>, TransportError> {
    assert!(m >= 1, "a ring needs at least one rank");
    assert_eq!(bind_addrs.len(), m, "one bind address per rank");
    let mut listeners: Vec<Box<dyn Listener>> = Vec::with_capacity(m);
    for addr in bind_addrs {
        listeners.push(transport.listen(addr)?);
    }
    let addrs: Vec<String> = listeners.iter().map(|l| l.local_addr()).collect();
    let mut rights = Vec::with_capacity(m);
    for r in 0..m {
        let hello = Hello::with_codec(r as u32, codec);
        rights.push(transport.connect(&addrs[(r + 1) % m], &hello)?);
    }
    let mut peers = Vec::with_capacity(m);
    for (r, (mut listener, right)) in listeners.into_iter().zip(rights).enumerate() {
        let (left, hello) = listener.accept()?;
        validate_neighbour(&hello, ((r + m - 1) % m) as u32, codec)?;
        peers.push(RingPeer {
            rank: r as u32,
            peers: m as u32,
            left,
            right,
        });
    }
    Ok(peers)
}

/// Form one rank's ring links in a distributed setting: the caller has
/// already bound `listener` and learned its right neighbour's address (the
/// dist runtime relays addresses through the server via `RING_ADDR`
/// frames). Connects right first — every rank's listener exists before any
/// address was handed out, so the connect never blocks on a remote accept —
/// then accepts the left neighbour and validates rank and codec.
pub fn connect_ring(
    transport: &dyn Transport,
    listener: &mut dyn Listener,
    right_addr: &str,
    rank: u32,
    peers: u32,
    codec: WireCodec,
) -> Result<RingPeer, TransportError> {
    assert!(peers >= 1 && rank < peers, "rank out of range");
    let right = transport.connect(right_addr, &Hello::with_codec(rank, codec))?;
    let (left, hello) = listener.accept()?;
    validate_neighbour(&hello, (rank + peers - 1) % peers, codec)?;
    Ok(RingPeer {
        rank,
        peers,
        left,
        right,
    })
}

/// What one [`RingReducer`] call did on the wire, measured from the
/// outgoing link's counters (frame overhead included — these are the bytes
/// the [`CommLedger`](crate::metrics::CommLedger) hop column reports).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReduceOutcome {
    /// Bytes this rank transmitted on its right link during the reduction.
    pub hop_bytes_tx: u64,
    /// Frames this rank transmitted on its right link.
    pub hop_frames_tx: u64,
    /// Entries in the reduced result every rank now holds.
    pub result_nnz: usize,
    /// Entries this rank dropped (re-sparsification or non-selection) and
    /// folded into the residual — 0 when no residual was supplied *and* no
    /// budget applied.
    pub dropped_entries: usize,
}

/// Configuration of the aligned-sparsity (index-free) mode: a shared-seed
/// count sketch of `rows × buckets` cells and the number of coordinates
/// `k` every rank independently — and identically — selects from it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AlignedConfig {
    /// Sketch rows (median-of-rows estimation; odd values avoid averaging).
    pub rows: usize,
    /// Buckets per row. Estimation error shrinks as buckets grow; a few ×
    /// the expected nnz is the usual operating point.
    pub buckets: usize,
    /// Coordinates selected — the index-free reduction's payload size.
    pub k: usize,
    /// Shared hash seed. Must agree across ranks (all hash the same seed to
    /// the same cells, which is the whole point).
    pub seed: u64,
}

impl Default for AlignedConfig {
    fn default() -> Self {
        Self {
            rows: 3,
            buckets: 1024,
            k: 128,
            seed: 0x9e37_79b9_7f4a_7c15,
        }
    }
}

/// Per-hop entry budget restoring the ring's per-node advantage for a
/// method of target density `rho` over dimension `d` split into `m` chunks:
/// `⌈2ρd/m⌉` — twice the expected per-chunk message size, so pairwise
/// merges rarely drop while deep partial sums stay bounded (the dropped
/// mass folds into the caller's residual either way).
pub fn default_budget(rho: f32, d: u32, m: usize) -> usize {
    (((2.0 * rho as f64 * d as f64) / m.max(1) as f64).ceil() as usize).max(1)
}

/// Aligned-mode configuration matched to a target density: select
/// `k = ⌈ρd⌉` coordinates through a 3-row sketch with `≥ 4k` buckets per
/// row (rounded up to a power of two), seeded from the run seed so every
/// rank hashes identically.
pub fn aligned_for(rho: f32, d: u32, seed: u64) -> AlignedConfig {
    let k = ((rho as f64 * d as f64).ceil() as usize).clamp(1, d.max(1) as usize);
    AlignedConfig {
        rows: 3,
        buckets: (4 * k).next_power_of_two().max(64),
        k,
        seed: seed ^ 0xA11C_ED5E_1EC7_10F5,
    }
}

/// SplitMix64 finalizer — the per-cell hash of the shared sketch. Pure
/// arithmetic on `u64`, so identical on every platform and backend.
#[inline]
fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    x
}

/// Bucket and sign for coordinate `i` in sketch row `row`.
#[inline]
fn hash_cell(seed: u64, row: usize, i: u32, buckets: usize) -> (usize, f32) {
    let h = mix64(seed ^ ((row as u64) << 32) ^ i as u64);
    let bucket = ((h >> 1) % buckets as u64) as usize;
    let sign = if h & 1 == 1 { -1.0 } else { 1.0 };
    (bucket, sign)
}

/// Median of a small scratch slice (sorted in place, IEEE total order).
fn median(xs: &mut [f32]) -> f32 {
    xs.sort_unstable_by(|a, b| a.total_cmp(b));
    let n = xs.len();
    if n % 2 == 1 {
        xs[n / 2]
    } else {
        0.5 * (xs[n / 2 - 1] + xs[n / 2])
    }
}

fn fold_residual(res: Option<&mut FeedbackState>, lo: u32, dropped: &[(u32, f32)]) {
    if let Some(res) = res {
        let decay = res.decay();
        let seg = res.layer_residual_mut(0);
        for &(i, v) in dropped {
            seg[(lo + i) as usize] += decay * v;
        }
    }
}

/// Stamp the hop frame's trace context: ring links are version-homogeneous
/// (both ends of every link run this binary's [`frame::TRANSPORT_VERSION`]),
/// so hops always carry `(round, sender-rank, seq)` — the merger links the
/// resulting `frame_tx`/`frame_rx` pairs into cross-rank flow arrows.
/// Stamping is version-, not telemetry-, dependent: the bytes on the wire
/// are identical whether or not anything records, which is what keeps the
/// telemetry-on/off runs bitwise-equal end to end.
fn stamp_hop(frame_buf: &mut Vec<u8>, sender: u32) {
    frame::stamp_ctx(
        frame_buf,
        frame::TraceCtx {
            round: crate::trace::current_round(),
            sender,
            seq: crate::trace::next_flow_seq(),
        },
    );
}

/// Encode `sg` as a one-message `WireBatch` and send it as a vectored
/// `SPARSE_REDUCE` frame (header segment + payload segment, one wire frame).
fn send_sparse_hop(
    right: &mut dyn Connection,
    frame_buf: &mut Vec<u8>,
    payload: &mut Vec<u8>,
    sender: u32,
    chunk: u32,
    phase: u8,
    sg: &SparseGrad,
    codec: WireCodec,
) -> Result<(), TransportError> {
    coding::encode_batch(&[sg], codec, payload);
    frame::encode_sparse_reduce_prefix(frame_buf, chunk, phase);
    stamp_hop(frame_buf, sender);
    let mut sp = crate::trace::span(crate::trace::Stage::Hop);
    sp.bytes((frame_buf.len() + payload.len()) as u64);
    right.send_vectored(&[frame_buf.as_slice(), payload.as_slice()])
}

/// Send a raw little-endian `f32` slice as a `SPARSE_REDUCE` frame — the
/// index-free hop payload of the aligned mode.
fn send_raw_hop(
    right: &mut dyn Connection,
    frame_buf: &mut Vec<u8>,
    payload: &mut Vec<u8>,
    sender: u32,
    chunk: u32,
    phase: u8,
    values: &[f32],
) -> Result<(), TransportError> {
    payload.clear();
    payload.reserve(values.len() * 4);
    for v in values {
        payload.extend_from_slice(&v.to_le_bytes());
    }
    frame::encode_sparse_reduce_prefix(frame_buf, chunk, phase);
    stamp_hop(frame_buf, sender);
    let mut sp = crate::trace::span(crate::trace::Stage::Hop);
    sp.bytes((frame_buf.len() + payload.len()) as u64);
    right.send_vectored(&[frame_buf.as_slice(), payload.as_slice()])
}

/// Receive one `SPARSE_REDUCE` frame into `rx` and return the byte range
/// of its payload within `rx`, refusing anything but the chunk/phase the
/// fixed ring schedule expects next.
fn recv_hop(
    left: &mut dyn Connection,
    rx: &mut Vec<u8>,
    expect_chunk: u32,
    expect_phase: u8,
) -> Result<std::ops::Range<usize>, TransportError> {
    left.recv(rx)?;
    match frame::decode(&rx[..])? {
        MsgView::SparseReduce { chunk, phase, payload }
            if chunk == expect_chunk && phase == expect_phase =>
        {
            let start = payload.as_ptr() as usize - rx.as_ptr() as usize;
            Ok(start..start + payload.len())
        }
        MsgView::SparseReduce { .. } => {
            Err(TransportError::UnexpectedMessage("hop out of ring schedule"))
        }
        _ => Err(TransportError::UnexpectedMessage("expected sparse-reduce hop")),
    }
}

/// Parse a raw `f32` hop payload into `out` (exact length required).
fn decode_f32s(payload: &[u8], out: &mut [f32]) -> Result<(), TransportError> {
    if payload.len() != out.len() * 4 {
        return Err(TransportError::UnexpectedMessage("raw hop length mismatch"));
    }
    for (slot, ch) in out.iter_mut().zip(payload.chunks_exact(4)) {
        *slot = f32::from_le_bytes(ch.try_into().unwrap());
    }
    Ok(())
}

/// Parse a raw `f32` hop payload and left-fold it into `out`
/// (`out[j] = incoming[j] + out[j]` — incoming first, pinning the ring-order
/// associativity).
fn add_f32s(payload: &[u8], out: &mut [f32]) -> Result<(), TransportError> {
    if payload.len() != out.len() * 4 {
        return Err(TransportError::UnexpectedMessage("raw hop length mismatch"));
    }
    for (slot, ch) in out.iter_mut().zip(payload.chunks_exact(4)) {
        *slot = f32::from_le_bytes(ch.try_into().unwrap()) + *slot;
    }
    Ok(())
}

/// Reusable scratch + configuration for ring reductions. One per rank;
/// steady state performs no allocation beyond what message growth forces
/// (all buffers are retained across rounds, matching the compress-engine
/// scratch discipline used everywhere else in the crate).
pub struct RingReducer {
    codec: WireCodec,
    budget: Option<usize>,
    chunks: Vec<SparseGrad>,
    incoming: Vec<SparseGrad>,
    merged: SparseGrad,
    payload: Vec<u8>,
    frame_buf: Vec<u8>,
    rx: Vec<u8>,
    sub_lens: Vec<usize>,
    dropped: Vec<(u32, f32)>,
    sketch: Vec<f32>,
    sketches: Vec<f32>,
    est: Vec<(u32, f32)>,
    sel: Vec<u32>,
    vals: Vec<f32>,
    row_scratch: Vec<f32>,
}

impl RingReducer {
    /// `budget` caps the entry count of every sparse hop message (`None` =
    /// exact reduction, hop messages may grow by index union). The wire
    /// codec must match the ring links' handshake codec.
    pub fn new(codec: WireCodec, budget: Option<usize>) -> Self {
        Self {
            codec,
            budget,
            chunks: Vec::new(),
            incoming: Vec::new(),
            merged: SparseGrad::empty(0),
            payload: Vec::new(),
            frame_buf: Vec::new(),
            rx: Vec::new(),
            sub_lens: Vec::new(),
            dropped: Vec::new(),
            sketch: Vec::new(),
            sketches: Vec::new(),
            est: Vec::new(),
            sel: Vec::new(),
            vals: Vec::new(),
            row_scratch: Vec::new(),
        }
    }

    pub fn budget(&self) -> Option<usize> {
        self.budget
    }

    pub fn set_budget(&mut self, budget: Option<usize>) {
        self.budget = budget;
    }

    /// Split `input` into `m` chunk accumulators in chunk-local coordinates
    /// (everything promoted to exact values — partial sums lose the shared
    /// magnitude after the first merge anyway).
    fn split_chunks(&mut self, input: &SparseGrad, m: usize) {
        let d = input.d;
        if self.chunks.len() != m {
            self.chunks.resize_with(m, || SparseGrad::empty(0));
        }
        for (c, chunk) in self.chunks.iter_mut().enumerate() {
            let (lo, hi) = chunk_bounds(d, m as u32, c as u32);
            chunk.reset((hi - lo) as usize);
        }
        let (mut c, mut hi) = (0usize, chunk_bounds(d, m as u32, 0).1);
        let mut lo = 0u32;
        for (i, v) in merge::Entries::new(input) {
            // Entries ascend, so the chunk cursor only moves forward; every
            // valid index lands before the final chunk's `hi == d`.
            while i >= hi {
                c += 1;
                let b = chunk_bounds(d, m as u32, c as u32);
                lo = b.0;
                hi = b.1;
            }
            self.chunks[c].exact.push((i - lo, v));
        }
    }

    /// Budget-cap chunk `c` (global chunk id) and fold the dropped mass into
    /// the residual at global coordinates. Returns the number dropped.
    fn cap_chunk(
        &mut self,
        d: u32,
        m: usize,
        c: usize,
        residual: Option<&mut FeedbackState>,
    ) -> usize {
        let Some(budget) = self.budget else { return 0 };
        self.dropped.clear();
        merge::resparsify_top(&mut self.chunks[c], budget, &mut self.dropped);
        let (lo, _) = chunk_bounds(d, m as u32, c as u32);
        fold_residual(residual, lo, &self.dropped);
        self.dropped.len()
    }

    /// Decode a sparse hop payload into `self.incoming[0]`, validating the
    /// one-message batch shape and the chunk dimension.
    fn decode_sparse_hop(&mut self, payload_range: std::ops::Range<usize>, want_d: u32) -> Result<(), TransportError> {
        let payload = &self.rx[payload_range];
        coding::decode_batch_into(payload, &mut self.incoming, &mut self.sub_lens)
            .map_err(|_| TransportError::UnexpectedMessage("undecodable hop payload"))?;
        if self.incoming.len() != 1 {
            return Err(TransportError::UnexpectedMessage("hop payload is not one message"));
        }
        if self.incoming[0].d != want_d {
            return Err(TransportError::UnexpectedMessage("hop chunk dimension mismatch"));
        }
        Ok(())
    }

    /// Ring reduce-scatter + all-gather of sparse messages. Every rank calls
    /// this with its local message (all ranks must pass the same `d`); on
    /// return `out` holds the bitwise-identical reduced sum on every rank.
    ///
    /// Under a hop `budget`, partial sums are re-sparsified before every
    /// send and the dropped `(index, value)` mass is folded into `residual`
    /// (scaled by its decay) at global coordinates — supply the same
    /// [`FeedbackState`] that corrects this rank's next local gradient and
    /// the ring inherits the top-k + error-feedback contraction.
    pub fn reduce(
        &mut self,
        peer: &mut RingPeer,
        input: &SparseGrad,
        out: &mut SparseGrad,
        mut residual: Option<&mut FeedbackState>,
    ) -> Result<ReduceOutcome, TransportError> {
        let d = input.d;
        let m = peer.peers as usize;
        let r = peer.rank as usize;
        if let Some(res) = residual.as_deref_mut() {
            res.ensure_layout(&[d as usize]);
        }
        let mut dropped_total = 0usize;
        if m <= 1 {
            out.reset(d as usize);
            out.exact.extend(merge::Entries::new(input));
            if let Some(budget) = self.budget {
                self.dropped.clear();
                merge::resparsify_top(out, budget, &mut self.dropped);
                dropped_total = self.dropped.len();
                fold_residual(residual.as_deref_mut(), 0, &self.dropped);
            }
            return Ok(ReduceOutcome {
                hop_bytes_tx: 0,
                hop_frames_tx: 0,
                result_nnz: out.nnz(),
                dropped_entries: dropped_total,
            });
        }
        let tx = peer.right.counters();
        let (bytes0, frames0) = (tx.bytes_tx(), tx.frames_tx());

        self.split_chunks(input, m);

        // Reduce-scatter: at step s, send chunk (r−s) mod m right, receive
        // chunk (r−s−1) mod m from the left and merge it *incoming-first* —
        // chunk c's sum left-folds in ring order starting at rank c, which
        // is what makes the result backend-independent.
        for s in 0..(m - 1) {
            let sc = (r + m - s) % m;
            let rc = (r + m - s - 1) % m;
            dropped_total += self.cap_chunk(d, m, sc, residual.as_deref_mut());
            send_sparse_hop(
                peer.right.as_mut(),
                &mut self.frame_buf,
                &mut self.payload,
                peer.rank,
                sc as u32,
                PHASE_REDUCE_SCATTER,
                &self.chunks[sc],
                self.codec,
            )?;
            let range = recv_hop(
                peer.left.as_mut(),
                &mut self.rx,
                rc as u32,
                PHASE_REDUCE_SCATTER,
            )?;
            let (lo, hi) = chunk_bounds(d, m as u32, rc as u32);
            self.decode_sparse_hop(range, hi - lo)?;
            merge::merge_sum(&self.incoming[0], &self.chunks[rc], &mut self.merged);
            std::mem::swap(&mut self.chunks[rc], &mut self.merged);
        }

        // This rank now owns chunk (r+1) mod m — its fully reduced sum.
        // Cap it once; all-gather then forwards finalized chunks verbatim,
        // so every rank reconstructs identical bytes.
        let own = (r + 1) % m;
        dropped_total += self.cap_chunk(d, m, own, residual.as_deref_mut());
        for s in 0..(m - 1) {
            let sc = (r + 1 + m - s) % m;
            let rc = (r + m - s) % m;
            send_sparse_hop(
                peer.right.as_mut(),
                &mut self.frame_buf,
                &mut self.payload,
                peer.rank,
                sc as u32,
                PHASE_ALL_GATHER,
                &self.chunks[sc],
                self.codec,
            )?;
            let range = recv_hop(
                peer.left.as_mut(),
                &mut self.rx,
                rc as u32,
                PHASE_ALL_GATHER,
            )?;
            let (lo, hi) = chunk_bounds(d, m as u32, rc as u32);
            self.decode_sparse_hop(range, hi - lo)?;
            std::mem::swap(&mut self.chunks[rc], &mut self.incoming[0]);
        }

        out.reset(d as usize);
        for c in 0..m {
            let (lo, _) = chunk_bounds(d, m as u32, c as u32);
            out.exact
                .extend(merge::Entries::new(&self.chunks[c]).map(|(i, v)| (lo + i, v)));
        }
        Ok(ReduceOutcome {
            hop_bytes_tx: tx.bytes_tx() - bytes0,
            hop_frames_tx: tx.frames_tx() - frames0,
            result_nnz: out.nnz(),
            dropped_entries: dropped_total,
        })
    }

    /// Aligned-sparsity reduction: ranks agree on one top-`k` index set via
    /// a shared-seed count sketch, then reduce the `k` values index-free
    /// (raw `f32` hops, no index bytes on the wire). The selected
    /// coordinates carry their exact sums — the sketch decides *which*
    /// coordinates travel, never their values. Local entries outside the
    /// agreed set fold into `residual`.
    pub fn reduce_aligned(
        &mut self,
        peer: &mut RingPeer,
        cfg: &AlignedConfig,
        input: &SparseGrad,
        out: &mut SparseGrad,
        mut residual: Option<&mut FeedbackState>,
    ) -> Result<ReduceOutcome, TransportError> {
        assert!(cfg.rows > 0 && cfg.buckets > 0, "sketch must have cells");
        let d = input.d;
        let m = peer.peers as usize;
        let r = peer.rank as usize;
        let k = cfg.k.min(d as usize);
        let cells = cfg.rows * cfg.buckets;
        if let Some(res) = residual.as_deref_mut() {
            res.ensure_layout(&[d as usize]);
        }
        let tx = peer.right.counters();
        let (bytes0, frames0) = (tx.bytes_tx(), tx.frames_tx());

        // 1. Sketch the local message (O(nnz · rows)).
        {
            let mut sp = crate::trace::span(crate::trace::Stage::Sketch);
            sp.bytes((input.nnz() * cfg.rows) as u64);
            self.sketch.clear();
            self.sketch.resize(cells, 0.0);
            for (i, v) in merge::Entries::new(input) {
                for row in 0..cfg.rows {
                    let (b, sign) = hash_cell(cfg.seed, row, i, cfg.buckets);
                    self.sketch[row * cfg.buckets + b] += sign * v;
                }
            }
        }

        // 2. Ring all-gather every rank's sketch (chunk field = source
        // rank), then sum them in rank order 0..m — summing on arrival
        // would fold in a per-rank order and break the cross-rank
        // agreement the selection depends on.
        self.sketches.clear();
        self.sketches.resize(m * cells, 0.0);
        self.sketches[r * cells..(r + 1) * cells].copy_from_slice(&self.sketch);
        for s in 0..m.saturating_sub(1) {
            let src_tx = (r + m - s) % m;
            let src_rx = (r + m - s - 1) % m;
            send_raw_hop(
                peer.right.as_mut(),
                &mut self.frame_buf,
                &mut self.payload,
                peer.rank,
                src_tx as u32,
                PHASE_SKETCH,
                &self.sketches[src_tx * cells..(src_tx + 1) * cells],
            )?;
            let range = recv_hop(peer.left.as_mut(), &mut self.rx, src_rx as u32, PHASE_SKETCH)?;
            decode_f32s(
                &self.rx[range],
                &mut self.sketches[src_rx * cells..(src_rx + 1) * cells],
            )?;
        }
        self.sketch.clear();
        self.sketch.resize(cells, 0.0);
        for rank in 0..m {
            let seg = &self.sketches[rank * cells..(rank + 1) * cells];
            for (t, &v) in self.sketch.iter_mut().zip(seg) {
                *t += v;
            }
        }

        // 3. Identical top-k selection on every rank: median-of-rows
        // estimate for all d coordinates, |estimate| descending with
        // index-ascending tie-break, selected set sorted ascending.
        {
            let mut sp = crate::trace::span(crate::trace::Stage::Sketch);
            sp.bytes(d as u64);
            self.row_scratch.clear();
            self.row_scratch.resize(cfg.rows, 0.0);
            self.est.clear();
            self.est.reserve(d as usize);
            for i in 0..d {
                for row in 0..cfg.rows {
                    let (b, sign) = hash_cell(cfg.seed, row, i, cfg.buckets);
                    self.row_scratch[row] = sign * self.sketch[row * cfg.buckets + b];
                }
                self.est.push((i, median(&mut self.row_scratch)));
            }
            self.est.sort_unstable_by(|a, b| {
                b.1.abs().total_cmp(&a.1.abs()).then_with(|| a.0.cmp(&b.0))
            });
            self.sel.clear();
            self.sel.extend(self.est[..k].iter().map(|&(i, _)| i));
            self.sel.sort_unstable();
        }

        // 4. Local values at the agreed coordinates; everything else is
        // this rank's non-selected mass → residual.
        self.vals.clear();
        self.vals.resize(k, 0.0);
        self.dropped.clear();
        let mut j = 0usize;
        for (i, v) in merge::Entries::new(input) {
            while j < self.sel.len() && self.sel[j] < i {
                j += 1;
            }
            if j < self.sel.len() && self.sel[j] == i {
                self.vals[j] = v;
            } else {
                self.dropped.push((i, v));
            }
        }
        let dropped_total = self.dropped.len();
        fold_residual(residual.as_deref_mut(), 0, &self.dropped);

        // 5. Index-free reduce-scatter + all-gather over the k positions —
        // the same ring schedule as the sparse path, raw f32 payloads.
        for s in 0..m.saturating_sub(1) {
            let sc = (r + m - s) % m;
            let rc = (r + m - s - 1) % m;
            let (lo_s, hi_s) = chunk_bounds(k as u32, m as u32, sc as u32);
            send_raw_hop(
                peer.right.as_mut(),
                &mut self.frame_buf,
                &mut self.payload,
                peer.rank,
                sc as u32,
                PHASE_VALUES_RS,
                &self.vals[lo_s as usize..hi_s as usize],
            )?;
            let (lo_r, hi_r) = chunk_bounds(k as u32, m as u32, rc as u32);
            let range = recv_hop(peer.left.as_mut(), &mut self.rx, rc as u32, PHASE_VALUES_RS)?;
            add_f32s(&self.rx[range], &mut self.vals[lo_r as usize..hi_r as usize])?;
        }
        for s in 0..m.saturating_sub(1) {
            let sc = (r + 1 + m - s) % m;
            let rc = (r + m - s) % m;
            let (lo_s, hi_s) = chunk_bounds(k as u32, m as u32, sc as u32);
            send_raw_hop(
                peer.right.as_mut(),
                &mut self.frame_buf,
                &mut self.payload,
                peer.rank,
                sc as u32,
                PHASE_VALUES_AG,
                &self.vals[lo_s as usize..hi_s as usize],
            )?;
            let (lo_r, hi_r) = chunk_bounds(k as u32, m as u32, rc as u32);
            let range = recv_hop(peer.left.as_mut(), &mut self.rx, rc as u32, PHASE_VALUES_AG)?;
            decode_f32s(&self.rx[range], &mut self.vals[lo_r as usize..hi_r as usize])?;
        }

        out.reset(d as usize);
        out.exact
            .extend(self.sel.iter().zip(&self.vals).map(|(&i, &v)| (i, v)));
        Ok(ReduceOutcome {
            hop_bytes_tx: tx.bytes_tx() - bytes0,
            hop_frames_tx: tx.frames_tx() - frames0,
            result_nnz: out.nnz(),
            dropped_entries: dropped_total,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::feedback::FeedbackConfig;
    use crate::transport::InProcTransport;

    fn sg(d: u32, exact: &[(u32, f32)], shared: &[(u32, bool)], mag: f32) -> SparseGrad {
        SparseGrad {
            d,
            exact: exact.to_vec(),
            shared: shared.to_vec(),
            shared_mag: mag,
        }
    }

    fn dense_sum(inputs: &[SparseGrad]) -> Vec<f32> {
        let d = inputs[0].d as usize;
        let mut out = vec![0.0f32; d];
        for g in inputs {
            for (i, v) in g.to_dense().into_iter().enumerate() {
                out[i] += v;
            }
        }
        out
    }

    fn ring_addrs(tag: &str, m: usize) -> Vec<String> {
        (0..m).map(|r| format!("{tag}-{r}")).collect()
    }

    #[test]
    fn chunk_bounds_tile_the_dimension() {
        for &(d, m) in &[(10u32, 3u32), (7, 8), (1, 4), (1 << 20, 16), (5, 5)] {
            let mut prev_hi = 0u32;
            for c in 0..m {
                let (lo, hi) = chunk_bounds(d, m, c);
                assert_eq!(lo, prev_hi, "chunks must tile contiguously");
                assert!(hi >= lo);
                assert!(hi - lo <= d / m + 1, "widths differ by at most one");
                prev_hi = hi;
            }
            assert_eq!(prev_hi, d, "chunks must cover [0, d)");
        }
    }

    #[test]
    fn hash_cell_is_deterministic_and_in_range() {
        for i in 0..1000u32 {
            for row in 0..4usize {
                let (b1, s1) = hash_cell(42, row, i, 64);
                let (b2, s2) = hash_cell(42, row, i, 64);
                assert_eq!((b1, s1.to_bits()), (b2, s2.to_bits()));
                assert!(b1 < 64);
                assert!(s1 == 1.0 || s1 == -1.0);
            }
        }
        // Different seeds decorrelate at least one of the first few cells.
        assert!((0..16u32).any(|i| hash_cell(1, 0, i, 64) != hash_cell(2, 0, i, 64)));
    }

    #[test]
    fn exact_ring_reduce_matches_dense_sum() {
        let m = 3;
        let inputs = vec![
            sg(13, &[(0, 1.0), (5, -2.0), (12, 4.0)], &[(3, true)], 0.5),
            sg(13, &[(5, 1.5), (7, 0.25)], &[(0, false), (9, true)], 2.0),
            sg(13, &[(2, -1.0), (12, 1.0)], &[], 0.0),
        ];
        let expect = dense_sum(&inputs);
        let transport = InProcTransport::new();
        let peers = form_ring_local(&transport, m, WireCodec::Raw, &ring_addrs("xring", m)).unwrap();
        let outs: Vec<SparseGrad> = std::thread::scope(|s| {
            let handles: Vec<_> = peers
                .into_iter()
                .zip(&inputs)
                .map(|(mut peer, input)| {
                    s.spawn(move || {
                        let mut red = RingReducer::new(WireCodec::Raw, None);
                        let mut out = SparseGrad::empty(0);
                        let oc = red.reduce(&mut peer, input, &mut out, None).unwrap();
                        assert!(oc.hop_bytes_tx > 0);
                        assert_eq!(oc.result_nnz, out.nnz());
                        out
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for out in &outs {
            let got = out.to_dense();
            for (g, e) in got.iter().zip(&expect) {
                assert!((g - e).abs() < 1e-6, "got {got:?}, expect {expect:?}");
            }
            // Bitwise identical across ranks, not merely close.
            assert_eq!(out.exact, outs[0].exact);
        }
    }

    #[test]
    fn budgeted_reduce_conserves_mass_through_residuals() {
        let m = 2;
        let inputs = vec![
            sg(8, &[(0, 3.0), (1, 0.1), (4, -2.0), (6, 0.2)], &[], 0.0),
            sg(8, &[(1, 0.3), (3, 5.0), (6, -0.1), (7, 1.0)], &[], 0.0),
        ];
        let expect: f32 = dense_sum(&inputs).iter().sum();
        let transport = InProcTransport::new();
        let peers = form_ring_local(&transport, m, WireCodec::Raw, &ring_addrs("bring", m)).unwrap();
        let results: Vec<(SparseGrad, f64, usize)> = std::thread::scope(|s| {
            let handles: Vec<_> = peers
                .into_iter()
                .zip(&inputs)
                .map(|(mut peer, input)| {
                    s.spawn(move || {
                        let mut red = RingReducer::new(WireCodec::Raw, Some(2));
                        let mut res = FeedbackState::new(FeedbackConfig::default());
                        let mut out = SparseGrad::empty(0);
                        let oc = red.reduce(&mut peer, input, &mut out, Some(&mut res)).unwrap();
                        let res_sum: f64 =
                            res.layer_residual(0).iter().map(|&x| x as f64).sum();
                        (out, res_sum, oc.dropped_entries)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(results[0].0.exact, results[1].0.exact);
        let result_sum: f32 = results[0].0.to_dense().iter().sum();
        let residual_sum: f64 = results.iter().map(|r| r.1).sum();
        assert!(
            (result_sum as f64 + residual_sum - expect as f64).abs() < 1e-5,
            "dropped mass must land in exactly one residual"
        );
        assert!(results.iter().any(|r| r.2 > 0), "budget 2 must drop entries");
    }

    #[test]
    fn single_rank_reduce_is_identity_with_zero_hops() {
        let transport = InProcTransport::new();
        let mut peers =
            form_ring_local(&transport, 1, WireCodec::Raw, &ring_addrs("sring", 1)).unwrap();
        let input = sg(6, &[(1, 2.0), (4, -1.0)], &[(5, false)], 0.5);
        let mut red = RingReducer::new(WireCodec::Raw, None);
        let mut out = SparseGrad::empty(0);
        let oc = red.reduce(&mut peers[0], &input, &mut out, None).unwrap();
        assert_eq!(oc.hop_bytes_tx, 0);
        assert_eq!(oc.hop_frames_tx, 0);
        assert_eq!(out.to_dense(), input.to_dense());
    }

    #[test]
    fn aligned_ranks_agree_and_carry_exact_sums() {
        let m = 3;
        let d = 32;
        // Three heavy coordinates spread across ranks; the rest is noise an
        // order of magnitude smaller.
        let inputs = vec![
            sg(d, &[(3, 10.0), (8, 0.2), (20, -0.1)], &[], 0.0),
            sg(d, &[(3, 2.0), (17, -12.0), (25, 0.3)], &[], 0.0),
            sg(d, &[(9, 8.0), (17, -1.0), (30, 0.15)], &[], 0.0),
        ];
        let expect = dense_sum(&inputs);
        let cfg = AlignedConfig {
            rows: 5,
            buckets: 256,
            k: 4,
            seed: 7,
        };
        let transport = InProcTransport::new();
        let peers = form_ring_local(&transport, m, WireCodec::Raw, &ring_addrs("aring", m)).unwrap();
        let outs: Vec<SparseGrad> = std::thread::scope(|s| {
            let handles: Vec<_> = peers
                .into_iter()
                .zip(&inputs)
                .map(|(mut peer, input)| {
                    s.spawn(move || {
                        let mut red = RingReducer::new(WireCodec::Raw, None);
                        let mut out = SparseGrad::empty(0);
                        let oc = red
                            .reduce_aligned(&mut peer, &cfg, input, &mut out, None)
                            .unwrap();
                        assert_eq!(oc.result_nnz, cfg.k);
                        out
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for out in &outs {
            assert_eq!(out.exact, outs[0].exact, "aligned selection must agree");
        }
        // Selected coordinates carry their exact dense sums — the sketch
        // only chooses which coordinates travel.
        for &(i, v) in &outs[0].exact {
            assert!(
                (v - expect[i as usize]).abs() < 1e-6,
                "coord {i}: got {v}, expect {}",
                expect[i as usize]
            );
        }
        // The three heavy hitters must be among the selected four.
        let sel: Vec<u32> = outs[0].exact.iter().map(|&(i, _)| i).collect();
        for heavy in [3u32, 9, 17] {
            assert!(sel.contains(&heavy), "heavy coord {heavy} missed: {sel:?}");
        }
    }
}
