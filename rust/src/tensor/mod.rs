//! Dense f32 linear algebra for the coordinator hot path and the pure-Rust
//! reference models (logistic regression, SVM).
//!
//! Everything here is deliberately simple and allocation-free: flat `&[f32]`
//! slices, row-major matrices, and loops written so LLVM auto-vectorizes them
//! (the paper highlights SIMD-friendliness of the greedy sparsifier; the same
//! applies to these kernels).

mod matrix;
pub use matrix::Matrix;

/// `y += alpha * x`
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// Dot product.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(x.len(), y.len());
    // 4-way unrolled accumulation: breaks the sequential FP dependency chain
    // so the loop vectorizes, and is more accurate than naive summation.
    let mut acc = [0.0f32; 4];
    let chunks = x.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += x[i] * y[i];
        acc[1] += x[i + 1] * y[i + 1];
        acc[2] += x[i + 2] * y[i + 2];
        acc[3] += x[i + 3] * y[i + 3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for i in chunks * 4..x.len() {
        s += x[i] * y[i];
    }
    s
}

/// Squared ℓ2 norm.
#[inline]
pub fn norm2_sq(x: &[f32]) -> f32 {
    dot(x, x)
}

/// ℓ1 norm.
#[inline]
pub fn norm1(x: &[f32]) -> f32 {
    let mut acc = [0.0f32; 4];
    let chunks = x.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += x[i].abs();
        acc[1] += x[i + 1].abs();
        acc[2] += x[i + 2].abs();
        acc[3] += x[i + 3].abs();
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for i in chunks * 4..x.len() {
        s += x[i].abs();
    }
    s
}

/// `x *= alpha`
#[inline]
pub fn scale(x: &mut [f32], alpha: f32) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Number of exactly-zero entries.
#[inline]
pub fn count_zeros(x: &[f32]) -> usize {
    x.iter().filter(|&&v| v == 0.0).count()
}

/// Elementwise `z = x - y` into `z`.
#[inline]
pub fn sub_into(x: &[f32], y: &[f32], z: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    assert_eq!(x.len(), z.len());
    for i in 0..x.len() {
        z[i] = x[i] - y[i];
    }
}

/// Numerically-stable sigmoid.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// `log(1 + exp(-x))`, stable for large |x| (logistic loss building block).
#[inline]
pub fn log1p_exp_neg(x: f32) -> f32 {
    if x >= 0.0 {
        (-x).exp().ln_1p()
    } else {
        -x + x.exp().ln_1p()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 10.0, 10.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 14.0, 16.0]);
    }

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f32> = (0..37).map(|i| i as f32 * 0.5 - 3.0).collect();
        let y: Vec<f32> = (0..37).map(|i| (i as f32).sin()).collect();
        let naive: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-3);
    }

    #[test]
    fn norms() {
        let x = [3.0, -4.0];
        assert_eq!(norm2_sq(&x), 25.0);
        assert_eq!(norm1(&x), 7.0);
    }

    #[test]
    fn norm1_odd_len() {
        let x = [1.0, -2.0, 3.0, -4.0, 5.0];
        assert_eq!(norm1(&x), 15.0);
    }

    #[test]
    fn scale_and_zeros() {
        let mut x = [1.0, 0.0, 2.0, 0.0];
        scale(&mut x, 3.0);
        assert_eq!(x, [3.0, 0.0, 6.0, 0.0]);
        assert_eq!(count_zeros(&x), 2);
    }

    #[test]
    fn sigmoid_stable() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(100.0) > 0.999_999);
        assert!(sigmoid(-100.0) < 1e-6);
        assert!(sigmoid(-100.0) >= 0.0);
    }

    #[test]
    fn log1p_exp_neg_stable() {
        // log(1+exp(-0)) = ln 2
        assert!((log1p_exp_neg(0.0) - std::f32::consts::LN_2).abs() < 1e-6);
        // large positive -> ~0, large negative -> ~ -x
        assert!(log1p_exp_neg(50.0) < 1e-6);
        assert!((log1p_exp_neg(-50.0) - 50.0).abs() < 1e-4);
        assert!(log1p_exp_neg(-1000.0).is_finite());
    }

    #[test]
    fn sub_into_works() {
        let x = [5.0, 6.0];
        let y = [1.0, 2.0];
        let mut z = [0.0; 2];
        sub_into(&x, &y, &mut z);
        assert_eq!(z, [4.0, 4.0]);
    }
}
