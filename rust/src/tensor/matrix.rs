//! Row-major dense matrix used by the synthetic datasets and pure-Rust models.

use super::{axpy, dot};

/// Row-major `rows × cols` f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// `y = A x` (y allocated by caller, len = rows).
    pub fn matvec_into(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for r in 0..self.rows {
            y[r] = dot(self.row(r), x);
        }
    }

    /// `y += alpha * Aᵀ r` where `r` has len = rows, `y` len = cols.
    pub fn matvec_t_acc(&self, alpha: f32, r: &[f32], y: &mut [f32]) {
        assert_eq!(r.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        for (i, &ri) in r.iter().enumerate() {
            if ri != 0.0 {
                axpy(alpha * ri, self.row(i), y);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_and_transpose() {
        // A = [[1,2],[3,4],[5,6]]
        let a = Matrix::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let x = [1.0, -1.0];
        let mut y = [0.0; 3];
        a.matvec_into(&x, &mut y);
        assert_eq!(y, [-1.0, -1.0, -1.0]);

        let r = [1.0, 0.0, 2.0];
        let mut g = [0.0; 2];
        a.matvec_t_acc(1.0, &r, &mut g);
        // Aᵀ r = [1*1+5*2, 2*1+6*2] = [11, 14]
        assert_eq!(g, [11.0, 14.0]);
    }

    #[test]
    fn accessors() {
        let mut m = Matrix::zeros(2, 3);
        m.set(1, 2, 7.0);
        assert_eq!(m.get(1, 2), 7.0);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.row(1), &[0.0, 0.0, 7.0]);
        m.row_mut(0)[0] = 1.0;
        assert_eq!(m.as_slice()[0], 1.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let _ = Matrix::from_vec(2, 2, vec![1.0; 3]);
    }
}
