//! Model-checked concurrency core (`cargo test -p gsparse --features model
//! --test model`). The vendored exhaustive-interleaving checker in
//! `gsparse::sync::model` serializes the real threads of the code under
//! test onto a token-passing scheduler and DFS-explores the scheduling
//! decisions, so these tests assert properties over *many* interleavings,
//! not one lucky one:
//!
//! * the `ShardPool` dispatch/completion/drop protocol can neither deadlock
//!   nor lose a completion, including when the `on_done` hook panics;
//! * the trace ring's owner-only `try_lock` claim: a concurrent drain makes
//!   the owner *drop* the event — never block, never corrupt the ring.
//!
//! Iteration caps keep the harness bounded; each test asserts at least two
//! distinct interleavings actually ran (the acceptance bar for the checker
//! being real and not a single-schedule rerun).

#![cfg(feature = "model")]

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use gsparse::sparsify::ShardPool;
use gsparse::sync::model::{check_with, Opts};
use gsparse::sync::{thread, Arc};
use gsparse::trace::{self, Recorder, Stage, TraceConfig};

#[test]
fn pool_dispatch_completion_and_drop_hold_under_all_schedules() {
    let report = check_with(Opts { max_iterations: 400 }, || {
        let pool = ShardPool::new(2);
        let outputs: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..3)
            .map(|i| {
                let slot = &outputs[i];
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    slot.store(i + 1, Ordering::Relaxed);
                });
                job
            })
            .collect();
        let mut done = 0usize;
        pool.run_streamed(jobs, |_| done += 1);
        assert_eq!(done, 3, "every job reports exactly once");
        for (i, s) in outputs.iter().enumerate() {
            assert_eq!(s.load(Ordering::Relaxed), i + 1, "job {i} ran");
        }
        drop(pool); // join the workers under the model scheduler
    });
    assert!(
        report.iterations >= 2,
        "checker must explore at least two interleavings: {report:?}"
    );
}

#[test]
fn pool_on_done_panic_always_drains_before_unwinding() {
    let report = check_with(Opts { max_iterations: 400 }, || {
        let ran = Arc::new(AtomicUsize::new(0));
        let pool = ShardPool::new(1);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
                .map(|_| {
                    let ran = Arc::clone(&ran);
                    let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                        ran.fetch_add(1, Ordering::Relaxed);
                    });
                    job
                })
                .collect();
            pool.run_streamed(jobs, |_| panic!("hook panic"));
        }));
        assert!(caught.is_err(), "hook panic must propagate");
        // The DrainGuard property, as a schedule-independent invariant: by
        // the time the unwind escapes run_streamed, every dispatched job
        // has finished — in *every* interleaving, not just the lucky ones.
        assert_eq!(ran.load(Ordering::Relaxed), 2);
        drop(pool);
    });
    assert!(report.iterations >= 2, "{report:?}");
}

#[test]
fn pool_worker_panic_mid_dispatch_loses_no_other_completion() {
    let report = check_with(Opts { max_iterations: 400 }, || {
        let ran = Arc::new(AtomicUsize::new(0));
        let pool = ShardPool::new(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..3)
                .map(|i| {
                    let ran = Arc::clone(&ran);
                    let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                        if i == 1 {
                            panic!("worker job panic");
                        }
                        ran.fetch_add(1, Ordering::Relaxed);
                    });
                    job
                })
                .collect();
            let mut ok = 0usize;
            pool.run_streamed(jobs, |_| ok += 1);
            unreachable!("a job panicked; run_streamed must re-raise (ok={ok})");
        }));
        assert!(caught.is_err());
        assert_eq!(
            ran.load(Ordering::Relaxed),
            2,
            "the non-panicking jobs must still have run to completion"
        );
        drop(pool); // and the pool must still shut down cleanly
    });
    assert!(report.iterations >= 2, "{report:?}");
}

/// The trace-ring claim from `trace::record`'s comment: only the owning
/// thread and the exporter take the ring lock; the owner uses `try_lock`
/// and *drops* the event under contention instead of ever blocking. Across
/// schedules that means a concurrent drain yields a total event count of
/// exactly 0 (contended: event dropped) or 1 (clean) — never a duplicate,
/// never a deadlock. Both outcomes must actually occur somewhere in the
/// explored schedules.
#[test]
fn trace_ring_drop_on_contention_and_clean_paths_both_reachable() {
    static SAW_CONTENDED: AtomicBool = AtomicBool::new(false);
    static SAW_CLEAN: AtomicBool = AtomicBool::new(false);
    let report = check_with(Opts { max_iterations: 400 }, || {
        let rec = Recorder::new(&TraceConfig::on()).expect("tracing on");
        let handle = rec.thread_handle(0);
        let child = thread::spawn(move || {
            let _guard = trace::install_handle(&handle);
            let mut span = trace::span(Stage::Encode);
            span.bytes(1);
            drop(span); // records via the ring's try_lock
        });
        let first = rec.drain(); // may hold the ring lock while the child pushes
        child.join().expect("recording thread clean");
        let rest = rec.drain();
        match first.len() + rest.len() {
            0 => SAW_CONTENDED.store(true, Ordering::Relaxed),
            1 => SAW_CLEAN.store(true, Ordering::Relaxed),
            n => panic!("ring corrupted: {n} events from one span"),
        }
    });
    assert!(report.iterations >= 2, "{report:?}");
    assert!(
        SAW_CLEAN.load(Ordering::Relaxed),
        "no schedule recorded the event cleanly"
    );
    assert!(
        SAW_CONTENDED.load(Ordering::Relaxed),
        "no schedule exercised the drop-on-contention path"
    );
}
