//! Acceptance tests of the `gsparse::trace` instrumentation — the pinned
//! tentpole invariant: **tracing on vs. off is bitwise-identical** on every
//! coordinator (spans read clocks and lengths, never the data path), plus
//! the metrics roll-up and exporter contracts the CI trace guard relies on.
//!
//! No test here touches `GSPARSE_TRACE` / `GSPARSE_TRACE_OUT` — the trace
//! switch goes through `SessionBuilder::trace` explicitly, so these tests
//! stay parallel-safe (the env-driven path is covered in
//! `tests/async_engine.rs` under a lock, and in the CI matrix leg).

use gsparse::api::{DistTask, MethodSpec, PsTask, Session, SyncTask};
use gsparse::data::gen_logistic;
use gsparse::model::{ConvexModel, LogisticModel};
use gsparse::trace::{self, Stage, TraceConfig};
use gsparse::transport::InProcTransport;

/// A session differing *only* in the trace switch.
fn session(traced: bool, seed: u64, workers: usize) -> Session {
    Session::builder()
        .method(MethodSpec::GSpar { rho: 0.1, iters: 2 })
        .workers(workers)
        .seed(seed)
        .trace(if traced { TraceConfig::on() } else { TraceConfig::Off })
        .build()
}

// ---------------------------------------------------------------------------
// Coordinator 1: synchronous Algorithm-1 trainer.
// ---------------------------------------------------------------------------

#[test]
fn sync_trace_on_off_bitwise_identical() {
    let ds = gen_logistic(128, 256, 0.6, 0.25, 91);
    let model = LogisticModel::new(1.0 / (10.0 * 128.0));
    let task = SyncTask {
        batch: 8,
        epochs: 8, // 4 rounds/epoch → 32 rounds
        lr: 1.0,
        ..SyncTask::default()
    };
    let off = session(false, 91, 4).train_convex(&task, &ds, &model);
    let on = session(true, 91, 4).train_convex(&task, &ds, &model);
    assert_eq!(off.final_loss(), on.final_loss(), "weights must not move");
    assert_eq!(off.ledger.messages, on.ledger.messages);
    assert_eq!(off.ledger.ideal_bits, on.ledger.ideal_bits);
    assert_eq!(off.ledger.wire_bytes, on.ledger.wire_bytes);
    assert_eq!(off.ledger.wire_bytes_by_codec, on.ledger.wire_bytes_by_codec);
    assert_eq!(off.ledger.measured_bytes, on.ledger.measured_bytes);
    assert_eq!(off.ledger.measured_frames, on.ledger.measured_frames);
    // Same loss curve, point for point.
    assert_eq!(off.points.len(), on.points.len());
    for (a, b) in off.points.iter().zip(&on.points) {
        assert_eq!(a.loss, b.loss);
    }
    // And the run itself made progress (tracing a dead run proves little).
    let f0 = model.loss(&ds, &vec![0.0; 256]);
    assert!(on.final_loss() < f0 * 0.9, "{f0} -> {}", on.final_loss());
}

// ---------------------------------------------------------------------------
// Coordinator 2: threaded leader/worker cluster (multi-layer).
// ---------------------------------------------------------------------------

#[test]
fn cluster_trace_on_off_bitwise_identical_and_metrics_line_up() {
    let dims = [96usize, 64];
    let workers = 2usize;
    let rounds = 5usize;
    let grads: Vec<Vec<Vec<f32>>> = (0..workers)
        .map(|w| {
            dims.iter()
                .enumerate()
                .map(|(l, &d)| gsparse::benchkit::skewed_gradient(d, (w * 11 + l) as u64, 0.1))
                .collect()
        })
        .collect();
    let run = |traced: bool| {
        let mut cluster = session(traced, 47, workers).cluster(&dims);
        let updates: Vec<_> = (0..rounds).map(|_| cluster.round(&grads)).collect();
        let metrics = cluster.trace_metrics();
        (updates, cluster.ledger.clone(), metrics)
    };
    let (off_upd, off_ledger, off_metrics) = run(false);
    let (on_upd, on_ledger, on_metrics) = run(true);

    // Bitwise identity: every decoded layer update, every ledger column.
    for (r, (a_round, b_round)) in off_upd.iter().zip(&on_upd).enumerate() {
        for (l, (a, b)) in a_round.iter().zip(b_round).enumerate() {
            assert_eq!(a.grad, b.grad, "round {r} layer {l} drifted under tracing");
            assert_eq!(a.upload_bytes, b.upload_bytes, "round {r} layer {l}");
            assert_eq!(a.ideal_bits, b.ideal_bits, "round {r} layer {l}");
        }
    }
    assert_eq!(off_ledger.wire_bytes, on_ledger.wire_bytes);
    assert_eq!(off_ledger.measured_bytes, on_ledger.measured_bytes);
    assert_eq!(off_ledger.measured_frames, on_ledger.measured_frames);
    assert_eq!(off_ledger.messages, on_ledger.messages);

    // Tracing off → no recorder, no snapshot. On → the roll-up's span
    // counters mirror the coordinator's structure exactly: one leader
    // round span per round, one push span per worker per round, and the
    // leader links' transport counters folded in under `link_w*`.
    assert!(off_metrics.is_none(), "Off must not allocate a recorder");
    let snap = on_metrics.expect("traced cluster must produce a snapshot");
    assert_eq!(snap.counter("round_events"), Some(rounds as u64));
    assert_eq!(snap.counter("push_events"), Some((workers * rounds) as u64));
    assert!(snap.counter("events_total").unwrap() > 0);
    assert!(
        snap.counter("link_w0_frames_rx").unwrap() > 0,
        "leader link counters must fold into the registry"
    );
    assert!(
        snap.histogram("round_duration_ns").is_some(),
        "per-stage latency histograms must be populated"
    );
    // The snapshot exporter is schema-stable hand-rolled JSON.
    let json = snap.to_json();
    assert!(json.starts_with("{\"schema\":\"gsparse-metrics-v1\""), "{json}");
}

// ---------------------------------------------------------------------------
// Coordinator 3: distributed runtime (threads over InProc channels).
// ---------------------------------------------------------------------------

#[test]
fn dist_threads_trace_on_off_bitwise_identical() {
    let task = DistTask {
        rounds: 24,
        n: 128,
        d: 96,
        batch: 4,
        reg: 1.0 / (10.0 * 128.0),
        ..DistTask::default()
    };
    let run = |traced: bool, addr: &str| {
        session(traced, 63, 2)
            .dist_threads(InProcTransport::new(), addr, &task)
            .unwrap()
    };
    let off = run(false, "trace-off");
    let on = run(true, "trace-on");
    // The digest is FNV-1a over every gradient payload in apply order —
    // equality means the traced run shipped bitwise-identical bytes.
    assert_eq!(off.grad_digest, on.grad_digest);
    assert_eq!(off.final_w, on.final_w);
    assert_eq!(off.versions, on.versions);
    assert_eq!(off.curve.ledger.wire_bytes, on.curve.ledger.wire_bytes);
    assert_eq!(
        off.curve.ledger.measured_frames,
        on.curve.ledger.measured_frames,
        "tracing must add zero frames to the wire"
    );
    assert_eq!(off.measured_tx_bytes, on.measured_tx_bytes);
    assert_eq!(off.measured_rx_bytes, on.measured_rx_bytes);

    // Server-side roll-up: one round span per block (H = 1 → per round).
    assert!(off.trace_metrics.is_none());
    let snap = on.trace_metrics.expect("traced dist run must report metrics");
    assert_eq!(snap.counter("round_events"), Some(task.rounds as u64));
    assert!(snap.counter("apply_events").unwrap() > 0, "server applies traced");
    // Ring capacity dwarfs the event volume at this scale, so the drop
    // counter the snapshot now carries must read exactly zero.
    assert_eq!(snap.counter("trace_dropped_total"), Some(0));
}

// ---------------------------------------------------------------------------
// Coordinator 4: SSP parameter server. The thread schedule is racy by
// design, so bitwise identity is claimed on the *budget-driven* columns
// (applied versions = the iteration budget), not on the race-dependent
// trajectory — plus trace transparency on the frame accounting identity
// that holds on every schedule.
// ---------------------------------------------------------------------------

#[test]
fn param_server_trace_is_transparent_and_reports_metrics() {
    let ds = gen_logistic(256, 128, 0.6, 0.25, 55);
    let model = LogisticModel::new(1.0 / (10.0 * 256.0));
    let task = PsTask {
        total_iterations: 400,
        ..PsTask::default()
    };
    let workers = 4usize;
    let run = |traced: bool| session(traced, 55, workers).param_server(&task, &ds, &model);
    let off = run(false);
    let on = run(true);
    assert_eq!(off.versions, 400, "H = 1: one applied push per iteration");
    assert_eq!(on.versions, 400);
    // Frame identity on both runs: handshakes plus exactly one push per
    // version — tracing adds nothing to the wire.
    assert_eq!(off.curve.ledger.measured_frames, workers as u64 + off.versions);
    assert_eq!(on.curve.ledger.measured_frames, workers as u64 + on.versions);
    let f0 = model.loss(&ds, &vec![0.0; 128]);
    assert!(off.final_loss < f0, "{f0} -> {}", off.final_loss);
    assert!(on.final_loss < f0, "{f0} -> {}", on.final_loss);

    assert!(off.trace_metrics.is_none());
    let snap = on.trace_metrics.expect("traced PS run must report metrics");
    // Every applied version was one worker-side push span.
    assert_eq!(snap.counter("push_events"), Some(on.versions));
    assert_eq!(snap.counter("apply_events"), Some(on.versions));
    assert!(snap.counter("pull_events").unwrap() > 0);
    assert!(
        snap.gauges.iter().any(|(n, _)| n == "staleness_stalls"),
        "PS-specific gauge must be registered"
    );
    assert!(snap.counter("link_w0_frames_tx").unwrap() > 0);
    assert_eq!(snap.counter("trace_dropped_total"), Some(0));
}

// ---------------------------------------------------------------------------
// Recorder + exporter contracts (what the CI trace guard parses).
// ---------------------------------------------------------------------------

#[test]
fn recorder_roundtrip_exports_chrome_and_jsonl() {
    let rec = trace::Recorder::new(&TraceConfig::On {
        capacity: 64,
        format: trace::TraceFormat::Chrome,
    })
    .expect("On must build a recorder");
    {
        let _guard = trace::install(&rec, 3);
        trace::set_round(7);
        {
            let mut s = trace::span(Stage::Encode);
            s.bytes(1234);
            s.layer(2);
        }
        trace::counter(Stage::FrameTx, 1238);
    }
    let events = rec.drain();
    assert_eq!(events.len(), 2);
    // Sorted by start time; identity fields survive the ring.
    assert!(events[0].t_start_ns <= events[1].t_start_ns);
    let enc = events.iter().find(|e| e.stage == Stage::Encode).unwrap();
    assert_eq!((enc.worker, enc.round, enc.layer, enc.bytes), (3, 7, 2, 1234));

    let chrome = trace::chrome_trace_json(&events);
    assert!(chrome.contains("\"traceEvents\":["), "{chrome}");
    assert!(chrome.contains("\"name\":\"encode\""), "{chrome}");
    assert!(chrome.contains("\"pid\":3"), "{chrome}");
    let jsonl = trace::jsonl(&events);
    assert_eq!(jsonl.lines().count(), events.len(), "one object per line");
    assert!(jsonl.lines().all(|l| l.starts_with('{') && l.ends_with('}')));

    // Draining is destructive; the rings restart empty.
    assert!(rec.drain().is_empty());
}

#[test]
fn ring_overwrites_oldest_and_counts_drops() {
    let rec = trace::Recorder::new(&TraceConfig::On {
        capacity: 4,
        format: trace::TraceFormat::Chrome,
    })
    .unwrap();
    {
        let _guard = trace::install(&rec, 0);
        for i in 0..10u64 {
            trace::counter(Stage::FrameRx, i);
        }
    }
    let events = rec.drain();
    assert_eq!(events.len(), 4, "ring must cap at capacity");
    // The survivors are the *newest* events.
    let bytes: Vec<u64> = events.iter().map(|e| e.bytes).collect();
    assert_eq!(bytes, vec![6, 7, 8, 9]);
    assert_eq!(rec.dropped(), 6, "overwritten events must be counted");
}
