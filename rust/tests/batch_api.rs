//! Acceptance tests of the unified Session API + batched multi-layer
//! pipeline (this PR's headline criteria):
//!
//! * for a ≥ 4-layer model at `d_total ≥ 2^18`, ρ = 0.01, the batched path
//!   decodes **bitwise-identical** per-layer updates while shipping
//!   **strictly fewer wire bytes** and **strictly fewer transport frames**
//!   per round, under both codecs;
//! * all four coordinators (sync, SSP parameter server, threaded cluster,
//!   TCP dist runtime) run from one [`Session`];
//! * the batched engine's messages equal the per-layer engine's messages
//!   for the same RNG stream.

use gsparse::api::{DistTask, MethodSpec, PsTask, Session, SyncTask};
use gsparse::coding::WireCodec;
use gsparse::model::ConvexModel;
use gsparse::rngkit::RandArray;
use gsparse::sparsify::{BatchCompressEngine, CompressEngine, SparseGrad};
use gsparse::transport::InProcTransport;

/// The headline criterion: ≥ 4 layers, `d_total = 2^18`, ρ = 0.01.
#[test]
fn batched_rounds_identical_updates_fewer_bytes_fewer_frames() {
    let dims = [1usize << 15; 8]; // 8 layers, d_total = 2^18
    assert!(dims.len() >= 4 && dims.iter().sum::<usize>() >= 1 << 18);
    let workers = 2;
    let grads: Vec<Vec<Vec<f32>>> = (0..workers)
        .map(|w| {
            dims.iter()
                .enumerate()
                .map(|(l, &d)| {
                    gsparse::benchkit::skewed_gradient(d, (w * 17 + l) as u64, 0.1)
                })
                .collect()
        })
        .collect();

    for codec in [WireCodec::Raw, WireCodec::Entropy] {
        let run = |batch: bool| {
            let mut cluster = Session::builder()
                .method(MethodSpec::GSpar { rho: 0.01, iters: 2 })
                .codec(codec)
                .workers(workers)
                .seed(2024)
                .batch_layers(batch)
                .build()
                .cluster(&dims);
            let upd = cluster.round(&grads);
            (upd, cluster.ledger.clone(), cluster.frames_received())
        };
        let (per_layer, pl_ledger, pl_frames) = run(false);
        let (batched, b_ledger, b_frames) = run(true);

        // Bitwise-identical decoded per-layer updates.
        for (l, (a, b)) in per_layer.iter().zip(&batched).enumerate() {
            assert_eq!(a.grad, b.grad, "{codec}: layer {l} decoded update drifted");
        }
        // Strictly fewer wire bytes…
        assert!(
            b_ledger.wire_bytes < pl_ledger.wire_bytes,
            "{codec}: batched wire {} !< per-layer {}",
            b_ledger.wire_bytes,
            pl_ledger.wire_bytes
        );
        // …and strictly fewer measured (framed) bytes…
        assert!(
            b_ledger.measured_bytes < pl_ledger.measured_bytes,
            "{codec}: batched measured {} !< per-layer {}",
            b_ledger.measured_bytes,
            pl_ledger.measured_bytes
        );
        // …and strictly fewer transport frames per round: per-layer ships
        // workers × L gradient frames, batched ships workers (handshakes
        // are identical on both sides).
        assert!(
            b_frames < pl_frames,
            "{codec}: batched frames {b_frames} !< per-layer {pl_frames}"
        );
        assert_eq!(b_frames, (workers * 2) as u64, "{codec}: hello + one batch frame");
        assert_eq!(
            pl_frames,
            (workers * (1 + dims.len())) as u64,
            "{codec}: hello + one frame per layer"
        );
    }
}

/// The engine-level half of the criterion: one fused batch invocation
/// produces exactly the messages the per-layer engine produces.
#[test]
fn batch_engine_bitwise_matches_per_layer_engine_at_2e18() {
    // Six uneven layers totalling exactly 2^18 coordinates.
    let dims = [1usize << 16, 3 << 15, 1 << 15, 1 << 14, 1 << 14, 1 << 15];
    assert_eq!(dims.iter().sum::<usize>(), 1 << 18);
    let layers: Vec<Vec<f32>> =
        dims.iter()
            .enumerate()
            .map(|(l, &d)| gsparse::benchkit::skewed_gradient(d, 7 + l as u64, 0.1))
            .collect();
    let refs: Vec<&[f32]> = layers.iter().map(|g| g.as_slice()).collect();

    // Per-layer reference: fresh engine per layer, one shared uniform
    // stream, layer order.
    let mut rand = RandArray::from_seed(0xACCE97, 1 << 19);
    let mut want = Vec::new();
    for g in &layers {
        let mut engine = CompressEngine::greedy(0.01, 2).with_sharding(1 << 14, usize::MAX, 1);
        let mut sg = SparseGrad::empty(0);
        engine.compress_sparse_into(g, &mut rand, &mut sg);
        want.push(sg);
    }

    // Batched: same seed, one invocation, pooled path forced on.
    let mut engine = BatchCompressEngine::greedy(0.01, 2).with_sharding(1 << 14, 1, 4);
    let mut rand = RandArray::from_seed(0xACCE97, 1 << 19);
    let (mut outs, mut pvs, mut wire) = (Vec::new(), Vec::new(), Vec::new());
    engine.compress_batch_into(
        &refs,
        WireCodec::Entropy,
        &mut rand,
        &mut outs,
        &mut wire,
        &mut pvs,
    );
    assert_eq!(outs, want, "batched messages drifted from the per-layer engine");

    // And the fused wire batch decodes back to the same messages while
    // undercutting the per-layer encodings.
    let mut back = Vec::new();
    let mut sub_lens = Vec::new();
    gsparse::coding::decode_batch_into(&wire, &mut back, &mut sub_lens).unwrap();
    assert_eq!(back, want);
    let singles: usize = want
        .iter()
        .map(|sg| gsparse::coding::encoded_len_with(sg, WireCodec::Entropy))
        .sum();
    assert!(
        wire.len() < singles,
        "batch {} !< per-layer encodings {singles}",
        wire.len()
    );
}

/// One `SessionBuilder` drives all four coordinators.
#[test]
fn one_session_runs_all_four_coordinators() {
    let session = Session::builder()
        .method(MethodSpec::GSpar { rho: 0.1, iters: 2 })
        .codec(WireCodec::from_env())
        .workers(2)
        .seed(7)
        .build();

    // 1. Synchronous Algorithm-1 trainer.
    let ds = gsparse::data::gen_logistic(128, 96, 0.6, 0.25, 7);
    let model = gsparse::model::LogisticModel::new(1.0 / (10.0 * 128.0));
    let f0 = model.loss(&ds, &vec![0.0; 96]);
    let sync_curve = session.train_convex(
        &SyncTask {
            epochs: 6,
            lr: 1.0,
            ..SyncTask::default()
        },
        &ds,
        &model,
    );
    assert!(sync_curve.final_loss() < f0);
    assert!(sync_curve.ledger.measured_bytes > 0);

    // 2. SSP parameter server.
    let ps = session.param_server(
        &PsTask {
            total_iterations: 400,
            ..PsTask::default()
        },
        &ds,
        &model,
    );
    assert_eq!(ps.versions, 400);
    assert!(ps.final_loss < f0);

    // 3. Threaded multi-layer cluster.
    let dims = [64usize, 32];
    let grads: Vec<Vec<Vec<f32>>> = (0..2)
        .map(|w| {
            dims.iter()
                .map(|&d| gsparse::benchkit::skewed_gradient(d, 40 + w as u64, 0.1))
                .collect()
        })
        .collect();
    let mut cluster = session.cluster(&dims);
    let upd = cluster.round(&grads);
    assert_eq!(upd.len(), dims.len());
    assert!(cluster.ledger.measured_bytes > 0);

    // 4. Distributed runtime (threads over the in-process transport).
    let report = session
        .dist_threads(
            InProcTransport::new(),
            "batch-api-dist",
            &DistTask {
                rounds: 20,
                n: 128,
                d: 96,
                reg: 1.0 / (10.0 * 128.0),
                ..DistTask::default()
            },
        )
        .expect("dist run");
    assert_eq!(report.versions, 40);
    assert!(report.final_loss < f0);
    // The compiled plan carries the session's knobs onto the wire.
    let plan = session.dist_plan(&DistTask::default());
    assert_eq!(plan.workers, 2);
    assert_eq!(plan.seed, 7);
    assert_eq!(plan.codec, session.codec());
}
