//! Cross-module integration tests that need no HLO artifacts: the full
//! Algorithm-1 pipeline (data → model → sparsify → encode → allreduce →
//! optimizer) and the Algorithm-4 async engine, exercised end to end.

use gsparse::api::{MethodSpec, Session, SyncTask};
use gsparse::config::{AsyncSvmConfig, Method, UpdateScheme};
use gsparse::coordinator::sync::{estimate_f_star, OptKind};
use gsparse::coordinator::AsyncSvmEngine;
use gsparse::data::{gen_logistic, gen_svm};
use gsparse::model::{ConvexModel, LogisticModel, SvmModel};

const N: usize = 256;
const D: usize = 512;
const C1: f32 = 0.6;
const C2: f32 = 0.25;
const REG: f32 = 1.0 / (10.0 * 256.0);
const SEED: u64 = 1234;

fn session(method: Method) -> Session {
    Session::builder()
        .method(MethodSpec::from_parts(method, 0.1, C2 * C1, 4))
        .workers(4)
        .seed(SEED)
        .build()
}

fn task(f_star: f64) -> SyncTask {
    SyncTask {
        batch: 8,
        epochs: 20,
        lr: 1.0,
        f_star,
        ..SyncTask::default()
    }
}

#[test]
fn full_pipeline_every_method_converges() {
    let ds = gen_logistic(N, D, C1, C2, SEED);
    let model = LogisticModel::new(REG);
    let f_star = estimate_f_star(&ds, &model, 300, 1.0);
    for &method in Method::all() {
        let mut t = task(f_star);
        if method == Method::TernGrad || method == Method::OneBit {
            t.lr = 0.5; // aggressive quantizers need a gentler base rate
        }
        let curve = session(method).train_convex(&t, &ds, &model);
        let first = curve.points.first().unwrap().loss;
        let last = curve.final_loss();
        // High-variance baselines (UniSp at ρ=0.1) legitimately converge
        // slowly under η ∝ 1/(t·var) — that is the paper's point — so the
        // smoke criterion is monotone progress, not speed.
        assert!(
            last < first * 0.92,
            "{method}: suboptimality {first} -> {last}"
        );
        assert!(last.is_finite(), "{method}");
    }
}

#[test]
fn paper_ordering_gspar_between_dense_and_unisp() {
    // Figures 1–2 shape: per data pass, dense ≤ GSpar ≤ UniSp in loss, and
    // GSpar ≪ dense in bits.
    let ds = gen_logistic(N, D, C1, C2, SEED);
    let model = LogisticModel::new(REG);
    let f_star = estimate_f_star(&ds, &model, 300, 1.0);
    let run = |method| session(method).train_convex(&task(f_star), &ds, &model);
    let dense = run(Method::Dense);
    let gspar = run(Method::GSpar);
    let unisp = run(Method::UniSp);
    assert!(dense.final_loss() <= gspar.final_loss() * 1.2);
    assert!(gspar.final_loss() <= unisp.final_loss() * 1.05);
    assert!(gspar.ledger.ideal_bits < dense.ledger.ideal_bits / 3);
    assert!(gspar.var_ratio < unisp.var_ratio);
}

#[test]
fn svrg_converges_faster_than_sgd_at_end() {
    use gsparse::coordinator::sync::SvrgVariant;
    let ds = gen_logistic(N, D, C1, C2, SEED);
    let model = LogisticModel::new(REG);
    let f_star = estimate_f_star(&ds, &model, 500, 1.0);
    let mut sgd_task = task(f_star);
    sgd_task.epochs = 30;
    let sgd = session(Method::GSpar).train_convex(&sgd_task, &ds, &model);
    let mut svrg_task = sgd_task.clone();
    svrg_task.lr = 0.3;
    svrg_task.opt = OptKind::Svrg(SvrgVariant::SparsifyFull);
    let svrg = session(Method::GSpar).train_convex(&svrg_task, &ds, &model);
    assert!(
        svrg.final_loss() < sgd.final_loss() * 1.5,
        "svrg {} vs sgd {}",
        svrg.final_loss(),
        sgd.final_loss()
    );
}

#[test]
fn async_engine_gspar_vs_dense_wallclock() {
    // Figure 9 shape: sparsified updates reach a given loss in less wall
    // time (fewer atomic conflicts + fewer writes).
    let ds = gen_svm(4096, 256, 0.01, 0.9, 55);
    let mk = |method| AsyncSvmConfig {
        n: 4096,
        d: 256,
        c1: 0.01,
        c2: 0.9,
        reg: 0.1,
        rho: 0.05,
        threads: 8,
        lr: 0.05,
        method,
        seed: 56,
        total_steps: 30_000,
        scheme: UpdateScheme::Atomic,
    };
    let dense = AsyncSvmEngine::new(mk(Method::Dense)).run(&ds);
    let gspar = AsyncSvmEngine::new(mk(Method::GSpar)).run(&ds);
    // The §5.3 mechanism: sparsification shrinks the set of shared-memory
    // coordinates each step touches, which is what reduces conflicts on a
    // real multicore. (On this 1-core testbed wall-clock ordering is not
    // asserted — see DESIGN.md §Substitutions; the fig9 bench reports it.)
    assert!(
        (gspar.updates as f64) < 0.3 * dense.updates as f64,
        "gspar touches {} coords vs dense {}",
        gspar.updates,
        dense.updates
    );
    assert!(
        gspar.conflicts <= dense.conflicts,
        "gspar conflicts {} vs dense {}",
        gspar.conflicts,
        dense.conflicts
    );
    // And still optimize.
    let f0 = SvmModel::new(0.1).loss(&ds, &vec![0.0; 256]);
    assert!(gspar.final_loss < f0, "loss {} vs f(0) {f0}", gspar.final_loss);
}

#[test]
fn theory_lemma3_sparsity_bound_holds() {
    // Construct (rho, s)-approximately sparse vectors and check
    // E||Q(g)||_0 <= (1+rho)s with eps = rho (closed-form solver).
    let mut rng = gsparse::rngkit::Xoshiro256pp::seed_from_u64(99);
    for _ in 0..50 {
        let d = 512;
        let s = 16 + rng.next_below(48) as usize;
        // s large coordinates, the rest tiny.
        let mut g = vec![0.0f32; d];
        for gi in g.iter_mut().take(s) {
            *gi = 1.0 + rng.next_f32();
        }
        for gi in g.iter_mut().skip(s) {
            *gi = rng.next_f32() * 0.002;
        }
        let l1_s: f64 = g[..s].iter().map(|&x| x.abs() as f64).sum();
        let l1_sc: f64 = g[s..].iter().map(|&x| x.abs() as f64).sum();
        let rho = (l1_sc / l1_s) as f32; // the tightest valid rho
        let mut p = Vec::new();
        let pv = gsparse::sparsify::closed_form_probs(&g, rho, &mut p);
        let bound = (1.0 + rho as f64) * s as f64;
        assert!(
            pv.expected_nnz <= bound * (1.0 + 1e-5) + 1e-9,
            "E nnz {} > (1+rho)s = {bound} (s={s}, rho={rho})",
            pv.expected_nnz
        );
    }
}

#[test]
fn theory_theorem4_coding_length_bound_holds() {
    // For the same construction, the idealized message cost must respect
    // s(b + log2 d) + min(rho s log2 d, d) + b.
    let mut rng = gsparse::rngkit::Xoshiro256pp::seed_from_u64(101);
    for _ in 0..50 {
        let d = 1024;
        let s = 8 + rng.next_below(56) as usize;
        let mut g = vec![0.0f32; d];
        for gi in g.iter_mut().take(s) {
            *gi = 2.0 + rng.next_f32();
        }
        for gi in g.iter_mut().skip(s) {
            *gi = rng.next_f32() * 0.001;
        }
        let l1_s: f64 = g[..s].iter().map(|&x| x.abs() as f64).sum();
        let l1_sc: f64 = g[s..].iter().map(|&x| x.abs() as f64).sum();
        let rho = (l1_sc / l1_s) as f32;
        let mut p = Vec::new();
        let pv = gsparse::sparsify::closed_form_probs(&g, rho, &mut p);
        let qb_mass = pv.expected_nnz - pv.num_exact as f64;
        let cost = gsparse::sparsify::hybrid_ideal_bits(pv.num_exact as u64, qb_mass, d);
        let bound = gsparse::coding::theorem4_bound_bits(s, rho as f64, d);
        // num_exact can be < s when the variance budget lets big coords
        // drop; the bound is for keeping S_k = S, so allow equality slack.
        assert!(
            cost <= bound + 64,
            "cost {cost} > Thm4 bound {bound} (s={s}, rho={rho})"
        );
    }
}
