//! Cross-module integration tests that need no HLO artifacts: the full
//! Algorithm-1 pipeline (data → model → sparsify → encode → allreduce →
//! optimizer) and the Algorithm-4 async engine, exercised end to end.

use gsparse::config::{AsyncSvmConfig, ConvexConfig, Method, UpdateScheme};
use gsparse::coordinator::sync::{estimate_f_star, train_convex, OptKind, TrainOptions};
use gsparse::coordinator::AsyncSvmEngine;
use gsparse::data::{gen_logistic, gen_svm};
use gsparse::model::{ConvexModel, LogisticModel, SvmModel};

fn cfg(method: Method) -> ConvexConfig {
    ConvexConfig {
        n: 256,
        d: 512,
        c1: 0.6,
        c2: 0.25,
        reg: 1.0 / (10.0 * 256.0),
        rho: 0.1,
        workers: 4,
        batch: 8,
        epochs: 20,
        lr: 1.0,
        method,
        seed: 1234,
        qsgd_bits: 4,
    }
}

#[test]
fn full_pipeline_every_method_converges() {
    let c = cfg(Method::GSpar);
    let ds = gen_logistic(c.n, c.d, c.c1, c.c2, c.seed);
    let model = LogisticModel::new(c.reg);
    let f_star = estimate_f_star(&ds, &model, 300, 1.0);
    for &method in Method::all() {
        let mut c = cfg(method);
        if method == Method::TernGrad || method == Method::OneBit {
            c.lr = 0.5; // aggressive quantizers need a gentler base rate
        }
        let opts = TrainOptions {
            f_star,
            ..Default::default()
        };
        let curve = train_convex(&c, &opts, &ds, &model);
        let first = curve.points.first().unwrap().loss;
        let last = curve.final_loss();
        // High-variance baselines (UniSp at ρ=0.1) legitimately converge
        // slowly under η ∝ 1/(t·var) — that is the paper's point — so the
        // smoke criterion is monotone progress, not speed.
        assert!(
            last < first * 0.92,
            "{method}: suboptimality {first} -> {last}"
        );
        assert!(last.is_finite(), "{method}");
    }
}

#[test]
fn paper_ordering_gspar_between_dense_and_unisp() {
    // Figures 1–2 shape: per data pass, dense ≤ GSpar ≤ UniSp in loss, and
    // GSpar ≪ dense in bits.
    let base = cfg(Method::Dense);
    let ds = gen_logistic(base.n, base.d, base.c1, base.c2, base.seed);
    let model = LogisticModel::new(base.reg);
    let f_star = estimate_f_star(&ds, &model, 300, 1.0);
    let run = |method| {
        let c = cfg(method);
        let opts = TrainOptions {
            f_star,
            ..Default::default()
        };
        train_convex(&c, &opts, &ds, &model)
    };
    let dense = run(Method::Dense);
    let gspar = run(Method::GSpar);
    let unisp = run(Method::UniSp);
    assert!(dense.final_loss() <= gspar.final_loss() * 1.2);
    assert!(gspar.final_loss() <= unisp.final_loss() * 1.05);
    assert!(gspar.ledger.ideal_bits < dense.ledger.ideal_bits / 3);
    assert!(gspar.var_ratio < unisp.var_ratio);
}

#[test]
fn svrg_converges_faster_than_sgd_at_end() {
    use gsparse::coordinator::sync::SvrgVariant;
    let mut c = cfg(Method::GSpar);
    c.epochs = 30;
    let ds = gen_logistic(c.n, c.d, c.c1, c.c2, c.seed);
    let model = LogisticModel::new(c.reg);
    let f_star = estimate_f_star(&ds, &model, 500, 1.0);
    let sgd = train_convex(
        &c,
        &TrainOptions {
            f_star,
            ..Default::default()
        },
        &ds,
        &model,
    );
    let mut csvrg = c.clone();
    csvrg.lr = 0.3;
    let svrg = train_convex(
        &csvrg,
        &TrainOptions {
            opt: OptKind::Svrg(SvrgVariant::SparsifyFull),
            f_star,
            ..Default::default()
        },
        &ds,
        &model,
    );
    assert!(
        svrg.final_loss() < sgd.final_loss() * 1.5,
        "svrg {} vs sgd {}",
        svrg.final_loss(),
        sgd.final_loss()
    );
}

#[test]
fn async_engine_gspar_vs_dense_wallclock() {
    // Figure 9 shape: sparsified updates reach a given loss in less wall
    // time (fewer atomic conflicts + fewer writes).
    let ds = gen_svm(4096, 256, 0.01, 0.9, 55);
    let mk = |method| AsyncSvmConfig {
        n: 4096,
        d: 256,
        c1: 0.01,
        c2: 0.9,
        reg: 0.1,
        rho: 0.05,
        threads: 8,
        lr: 0.05,
        method,
        seed: 56,
        total_steps: 30_000,
        scheme: UpdateScheme::Atomic,
    };
    let dense = AsyncSvmEngine::new(mk(Method::Dense)).run(&ds);
    let gspar = AsyncSvmEngine::new(mk(Method::GSpar)).run(&ds);
    // The §5.3 mechanism: sparsification shrinks the set of shared-memory
    // coordinates each step touches, which is what reduces conflicts on a
    // real multicore. (On this 1-core testbed wall-clock ordering is not
    // asserted — see DESIGN.md §Substitutions; the fig9 bench reports it.)
    assert!(
        (gspar.updates as f64) < 0.3 * dense.updates as f64,
        "gspar touches {} coords vs dense {}",
        gspar.updates,
        dense.updates
    );
    assert!(
        gspar.conflicts <= dense.conflicts,
        "gspar conflicts {} vs dense {}",
        gspar.conflicts,
        dense.conflicts
    );
    // And still optimize.
    let f0 = SvmModel::new(0.1).loss(&ds, &vec![0.0; 256]);
    assert!(gspar.final_loss < f0, "loss {} vs f(0) {f0}", gspar.final_loss);
}

#[test]
fn theory_lemma3_sparsity_bound_holds() {
    // Construct (rho, s)-approximately sparse vectors and check
    // E||Q(g)||_0 <= (1+rho)s with eps = rho (closed-form solver).
    let mut rng = gsparse::rngkit::Xoshiro256pp::seed_from_u64(99);
    for _ in 0..50 {
        let d = 512;
        let s = 16 + rng.next_below(48) as usize;
        // s large coordinates, the rest tiny.
        let mut g = vec![0.0f32; d];
        for gi in g.iter_mut().take(s) {
            *gi = 1.0 + rng.next_f32();
        }
        for gi in g.iter_mut().skip(s) {
            *gi = rng.next_f32() * 0.002;
        }
        let l1_s: f64 = g[..s].iter().map(|&x| x.abs() as f64).sum();
        let l1_sc: f64 = g[s..].iter().map(|&x| x.abs() as f64).sum();
        let rho = (l1_sc / l1_s) as f32; // the tightest valid rho
        let mut p = Vec::new();
        let pv = gsparse::sparsify::closed_form_probs(&g, rho, &mut p);
        let bound = (1.0 + rho as f64) * s as f64;
        assert!(
            pv.expected_nnz <= bound * (1.0 + 1e-5) + 1e-9,
            "E nnz {} > (1+rho)s = {bound} (s={s}, rho={rho})",
            pv.expected_nnz
        );
    }
}

#[test]
fn theory_theorem4_coding_length_bound_holds() {
    // For the same construction, the idealized message cost must respect
    // s(b + log2 d) + min(rho s log2 d, d) + b.
    let mut rng = gsparse::rngkit::Xoshiro256pp::seed_from_u64(101);
    for _ in 0..50 {
        let d = 1024;
        let s = 8 + rng.next_below(56) as usize;
        let mut g = vec![0.0f32; d];
        for gi in g.iter_mut().take(s) {
            *gi = 2.0 + rng.next_f32();
        }
        for gi in g.iter_mut().skip(s) {
            *gi = rng.next_f32() * 0.001;
        }
        let l1_s: f64 = g[..s].iter().map(|&x| x.abs() as f64).sum();
        let l1_sc: f64 = g[s..].iter().map(|&x| x.abs() as f64).sum();
        let rho = (l1_sc / l1_s) as f32;
        let mut p = Vec::new();
        let pv = gsparse::sparsify::closed_form_probs(&g, rho, &mut p);
        let qb_mass = pv.expected_nnz - pv.num_exact as f64;
        let cost = gsparse::sparsify::hybrid_ideal_bits(pv.num_exact as u64, qb_mass, d);
        let bound = gsparse::coding::theorem4_bound_bits(s, rho as f64, d);
        // num_exact can be < s when the variance budget lets big coords
        // drop; the bound is for keeping S_k = S, so allow equality slack.
        assert!(
            cost <= bound + 64,
            "cost {cost} > Thm4 bound {bound} (s={s}, rho={rho})"
        );
    }
}
