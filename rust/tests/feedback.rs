//! Acceptance tests of the error-feedback + local-step subsystem
//! (`gsparse::feedback`) — this PR's headline criteria:
//!
//! * `WithFeedback<TopK>` at ρ = 0.001 reaches a lower loss than plain
//!   top-k at **equal measured wire bytes** on a deterministic logistic-
//!   regression run;
//! * local-step rounds provably send **zero frames** (transport counter +
//!   `CommLedger` assertions on the cluster, sync, and SSP coordinators);
//! * the refactored `OneBitSgd` (= `WithFeedback<SignCompressor>`) is
//!   bitwise identical to the legacy bespoke residual loop;
//! * feedback state is deterministic across backends: InProc vs TCP and
//!   batched vs per-layer produce bitwise-identical decoded updates
//!   (threads vs OS processes is covered in `transport_tcp.rs`).

use gsparse::api::{MethodSpec, PsTask, Session, SyncTask};
use gsparse::coding::WireCodec;
use gsparse::coordinator::dist::{self, RunPlan};
use gsparse::coordinator::sync::OptKind;
use gsparse::data::gen_logistic;
use gsparse::feedback::FeedbackConfig;
use gsparse::model::{ConvexModel, LogisticModel};
use gsparse::rngkit::RandArray;
use gsparse::sparsify::{Compressed, CompressStats, Compressor, OneBitSgd};
use gsparse::transport::{InProcTransport, TcpTransport};

// ---------------------------------------------------------------------------
// Headline: biased top-k at ρ = 0.001 only works with the residual memory.
// ---------------------------------------------------------------------------

fn aggressive_topk_session(feedback: bool) -> Session {
    let mut builder = Session::builder()
        .method(MethodSpec::TopK { rho: 0.001 })
        .workers(4)
        .seed(515);
    if feedback {
        builder = builder.feedback(FeedbackConfig::default());
    }
    builder.build()
}

#[test]
fn topk_with_feedback_beats_plain_topk_at_equal_wire_bytes() {
    // d = 2048 at ρ = 0.001 → k = 3 coordinates per message: plain top-k
    // keeps hammering the few largest coordinates and stalls; with the
    // residual re-injected, every dropped coordinate eventually ships and
    // the run converges — at *identical* wire cost, because both runs send
    // exactly k survivors per message under the deterministic raw codec.
    let ds = gen_logistic(256, 2048, 0.6, 0.25, 515);
    let model = LogisticModel::new(1.0 / (10.0 * 256.0));
    let task = SyncTask {
        batch: 8,
        epochs: 100, // 8 rounds/epoch → 800 rounds
        lr: 1.0,
        opt: OptKind::SgdInvT, // same deterministic η_t = lr/t for both runs
        ..SyncTask::default()
    };
    let plain = aggressive_topk_session(false).train_convex(&task, &ds, &model);
    let fb = aggressive_topk_session(true).train_convex(&task, &ds, &model);

    // Equal communication, measured three ways.
    assert_eq!(plain.ledger.messages, fb.ledger.messages);
    assert_eq!(
        plain.ledger.wire_bytes, fb.ledger.wire_bytes,
        "k survivors per message ⇒ byte-identical wire cost"
    );
    assert_eq!(plain.ledger.measured_bytes, fb.ledger.measured_bytes);

    // Strictly better optimization at that cost (deterministic run, so a
    // strict inequality is a stable criterion), plus genuine absolute
    // progress that plain top-k at 3/2048 coordinates cannot match early.
    let f0 = model.loss(&ds, &vec![0.0; 2048]);
    assert!(
        fb.final_loss() < plain.final_loss(),
        "feedback {} must beat plain top-k {} at equal bytes (f0 = {f0})",
        fb.final_loss(),
        plain.final_loss()
    );
    assert!(
        fb.final_loss() < f0 * 0.8,
        "feedback top-k must make real progress: {f0} -> {}",
        fb.final_loss()
    );
}

// ---------------------------------------------------------------------------
// Headline: the OneBitSgd refactor is bitwise-identical to the old loop.
// ---------------------------------------------------------------------------

/// The pre-refactor 1Bit-SGD implementation, verbatim (bespoke residual
/// loop fused with the sign quantizer) — the reference the shared-subsystem
/// composition must reproduce bit for bit.
struct LegacyOneBit {
    error: Vec<f32>,
}

impl LegacyOneBit {
    fn new() -> Self {
        Self { error: Vec::new() }
    }

    fn compress_into(&mut self, g: &[f32], out: &mut Compressed) -> CompressStats {
        let d = g.len();
        if self.error.len() != d {
            self.error = vec![0.0; d];
        }
        let mut pos_sum = 0.0f64;
        let mut pos_n = 0u64;
        let mut neg_sum = 0.0f64;
        let mut neg_n = 0u64;
        for i in 0..d {
            let c = g[i] + self.error[i];
            if c >= 0.0 {
                pos_sum += c as f64;
                pos_n += 1;
            } else {
                neg_sum += (-c) as f64;
                neg_n += 1;
            }
        }
        let pos_mag = if pos_n > 0 { (pos_sum / pos_n as f64) as f32 } else { 0.0 };
        let neg_mag = if neg_n > 0 { (neg_sum / neg_n as f64) as f32 } else { 0.0 };
        if !matches!(out, Compressed::Dense(_)) {
            *out = Compressed::Dense(Vec::new());
        }
        let Compressed::Dense(dense) = out else {
            unreachable!("just set to Dense")
        };
        dense.clear();
        let mut nnz = 0u64;
        for i in 0..d {
            let c = g[i] + self.error[i];
            let (s, q) = if c >= 0.0 { (1i8, pos_mag) } else { (-1i8, -neg_mag) };
            self.error[i] = c - q;
            if q != 0.0 {
                nnz += 1;
            }
            dense.push(match if q == 0.0 { 0 } else { s } {
                1 => pos_mag,
                -1 => -neg_mag,
                _ => 0.0,
            });
        }
        CompressStats {
            expected_nnz: nnz as f64,
            ideal_bits: d as u64 + 2 * 32,
        }
    }
}

#[test]
fn onebit_refactor_is_bitwise_identical_to_the_legacy_loop() {
    let d = 128;
    let mut rng = gsparse::rngkit::Xoshiro256pp::seed_from_u64(99);
    let mut rand = RandArray::from_seed(100, 1 << 10);
    let mut legacy = LegacyOneBit::new();
    let mut refactored = OneBitSgd::new();
    let mut msg_old = Compressed::Dense(Vec::new());
    let mut msg_new = Compressed::Dense(Vec::new());
    for step in 0..300 {
        // Fresh gradient every step so the residual actually evolves.
        let g: Vec<f32> = (0..d).map(|_| (rng.next_gaussian() * 0.4) as f32).collect();
        let s_old = legacy.compress_into(&g, &mut msg_old);
        let s_new = refactored.compress_into(&g, &mut rand, &mut msg_new);
        assert_eq!(s_old.expected_nnz, s_new.expected_nnz, "step {step}");
        assert_eq!(s_old.ideal_bits, s_new.ideal_bits, "step {step}");
        let (Compressed::Dense(a), Compressed::Dense(b)) = (&msg_old, &msg_new) else {
            panic!("both sides must produce dense messages");
        };
        assert_eq!(a, b, "step {step}: decoded messages diverged");
        // The carried residual must match bitwise too.
        assert_eq!(
            legacy.error.as_slice(),
            refactored.residual(),
            "step {step}: residuals diverged"
        );
    }
    // A dimension change resets both the same way.
    let g2 = vec![0.5f32; 32];
    let s_old = legacy.compress_into(&g2, &mut msg_old);
    let s_new = refactored.compress_into(&g2, &mut rand, &mut msg_new);
    assert_eq!(s_old.expected_nnz, s_new.expected_nnz);
    assert_eq!(legacy.error.as_slice(), refactored.residual());
}

// ---------------------------------------------------------------------------
// Headline: local-step rounds ship zero frames / zero bytes.
// ---------------------------------------------------------------------------

#[test]
fn cluster_local_step_rounds_send_zero_frames() {
    let dims = [64usize, 32];
    let workers = 2usize;
    let grads: Vec<Vec<Vec<f32>>> = (0..workers)
        .map(|w| {
            dims.iter()
                .enumerate()
                .map(|(l, &d)| gsparse::benchkit::skewed_gradient(d, (w * 7 + l) as u64, 0.1))
                .collect()
        })
        .collect();
    let mut cluster = Session::builder()
        .method(MethodSpec::TopK { rho: 0.2 })
        .feedback(FeedbackConfig::default())
        .local_steps(3)
        .workers(workers)
        .seed(81)
        .build()
        .cluster(&dims);
    assert_eq!(cluster.comm_schedule().period(), 3);
    let hello_frames = cluster.frames_received();
    assert_eq!(hello_frames, workers as u64, "one handshake per worker");

    let mut comm_rounds = 0u64;
    for t in 1..=7u64 {
        let before = cluster.frames_received();
        let upd = cluster.round(&grads);
        let after = cluster.frames_received();
        if t % 3 == 0 {
            comm_rounds += 1;
            assert!(after > before, "round {t} must synchronize");
            assert!(upd.iter().any(|u| u.upload_bytes > 0));
        } else {
            // The provable zero-traffic criterion: not one frame, not one
            // byte, and an all-zero update.
            assert_eq!(after, before, "local round {t} leaked a frame");
            assert!(upd.iter().all(|u| u.upload_bytes == 0 && u.ideal_bits == 0));
            assert!(upd
                .iter()
                .all(|u| u.grad.iter().all(|&v| v == 0.0)));
        }
    }
    assert_eq!(comm_rounds, 2);
    // Per-layer frames: one per (worker, layer) per comm round, plus the
    // hellos — mirrored by the ledger's frame/message columns.
    assert_eq!(
        cluster.frames_received(),
        workers as u64 * (1 + comm_rounds * dims.len() as u64)
    );
    assert_eq!(cluster.ledger.measured_frames, cluster.frames_received());
    assert_eq!(
        cluster.ledger.messages,
        comm_rounds * (workers * dims.len()) as u64
    );
    // Round 7 left a partial block pending: `flush` ships it (the
    // cluster-side analogue of the sync/dist final-round flush), and a
    // second flush is a no-op.
    let flushed = cluster.flush().expect("round 7 accumulated a partial block");
    assert!(flushed.iter().any(|u| u.upload_bytes > 0));
    assert_eq!(
        cluster.ledger.messages,
        (comm_rounds + 1) * (workers * dims.len()) as u64
    );
    assert!(cluster.flush().is_none(), "nothing pending after a flush");
}

#[test]
fn sync_local_steps_cut_messages_and_bytes() {
    let ds = gen_logistic(128, 256, 0.6, 0.25, 77);
    let model = LogisticModel::new(1.0 / (10.0 * 128.0));
    let task = SyncTask {
        batch: 8,
        epochs: 16, // 4 rounds/epoch → 64 rounds
        lr: 1.0,
        ..SyncTask::default()
    };
    let run = |h: usize| {
        Session::builder()
            .method(MethodSpec::GSpar { rho: 0.1, iters: 2 })
            .workers(4)
            .seed(77)
            .local_steps(h)
            .build()
            .train_convex(&task, &ds, &model)
    };
    let every = run(1);
    let local = run(4);
    // 64 rounds at H = 4 → 16 comm rounds × 4 workers.
    assert_eq!(local.ledger.messages, 16 * 4);
    assert_eq!(every.ledger.messages, 64 * 4);
    assert!(
        local.ledger.wire_bytes < every.ledger.wire_bytes / 3,
        "H=4 wire {} should be well under a third of H=1's {}",
        local.ledger.wire_bytes,
        every.ledger.wire_bytes
    );
    assert!(local.ledger.measured_bytes < every.ledger.measured_bytes / 3);
    // Frames: hello + one grad frame per message on each worker link
    // (counted on both the worker and master ends of the in-process pair
    // is not double-counted: the master-side counters are the source).
    assert_eq!(local.ledger.measured_frames, 4 + local.ledger.messages);
    // The infrequent schedule still optimizes.
    let f0 = model.loss(&ds, &vec![0.0; 256]);
    assert!(local.final_loss() < f0 * 0.9, "{f0} -> {}", local.final_loss());
    // And the every-round run is bitwise unaffected by the new machinery.
    let every2 = run(1);
    assert_eq!(every.final_loss(), every2.final_loss());
    assert_eq!(every.ledger.wire_bytes, every2.ledger.wire_bytes);
}

#[test]
fn ps_local_steps_push_fewer_frames() {
    let ds = gen_logistic(256, 128, 0.6, 0.25, 71);
    let model = LogisticModel::new(1.0 / (10.0 * 256.0));
    let task = PsTask {
        total_iterations: 800,
        ..PsTask::default()
    };
    let run = |h: usize| {
        Session::builder()
            .method(MethodSpec::GSpar { rho: 0.1, iters: 2 })
            .workers(4)
            .seed(42)
            .local_steps(h)
            .build()
            .param_server(&task, &ds, &model)
    };
    let every = run(1);
    let local = run(4);
    assert_eq!(every.versions, 800);
    // 800 claimed iterations in blocks of ≤ 4 → at least 200 pushes, at
    // most a few more when the budget runs out mid-block per worker.
    assert!(
        (200u64..=204).contains(&local.versions),
        "versions {}",
        local.versions
    );
    assert_eq!(local.curve.ledger.messages, local.versions);
    // The zero-frame proof for the async coordinator: the only frames on
    // the links are the handshakes plus exactly one push per version —
    // local iterations never touch the transport.
    assert_eq!(local.curve.ledger.measured_frames, 4 + local.versions);
    assert!(local.curve.ledger.messages * 3 < every.curve.ledger.messages);
    assert!(local.wire_bytes * 3 < every.wire_bytes);
    let f0 = model.loss(&ds, &vec![0.0; 128]);
    assert!(local.final_loss < f0, "{f0} -> {}", local.final_loss);
}

// ---------------------------------------------------------------------------
// Headline: feedback determinism across backends and paths.
// ---------------------------------------------------------------------------

#[test]
fn dist_feedback_local_steps_identical_across_inproc_and_tcp() {
    // Residual state and decoded updates must be bitwise identical between
    // the channel backend and real loopback sockets, with feedback AND a
    // local-step schedule engaged (the strictest composition).
    let cfg = RunPlan {
        workers: 2,
        rounds: 48,
        local_steps: 4,
        n: 192,
        d: 96,
        batch: 4,
        seed: 33,
        reg: 1.0 / (10.0 * 192.0),
        method: gsparse::config::Method::TopK,
        rho: 0.03,
        feedback: Some(FeedbackConfig::default()),
        ..Default::default()
    };
    let inproc = dist::run_threads(InProcTransport::new(), "fb-parity", &cfg).unwrap();
    let tcp = dist::run_threads(TcpTransport::new(), "127.0.0.1:0", &cfg).unwrap();
    assert_eq!(inproc.grad_digest, tcp.grad_digest);
    assert_eq!(inproc.final_w, tcp.final_w);
    assert_eq!(
        inproc.curve.ledger.measured_bytes,
        tcp.curve.ledger.measured_bytes
    );
    assert_eq!(
        inproc.curve.ledger.measured_frames,
        tcp.curve.ledger.measured_frames
    );
    // 48 rounds at H = 4 → 12 pushes per worker.
    assert_eq!(inproc.versions, 24);
}

#[test]
fn cluster_feedback_batched_matches_per_layer_bitwise() {
    // The per-layer residual layout inside one batched WithFeedback must
    // reproduce the independent per-layer instances exactly, round after
    // round, under both codecs — so turning on `batch_layers` changes wire
    // framing, never the math.
    let dims = [700usize, 256, 128, 64];
    let workers = 2usize;
    let grads: Vec<Vec<Vec<f32>>> = (0..workers)
        .map(|w| {
            dims.iter()
                .enumerate()
                .map(|(l, &d)| gsparse::benchkit::skewed_gradient(d, (w * 17 + l) as u64, 0.1))
                .collect()
        })
        .collect();
    for (spec, codec) in [
        (MethodSpec::TopK { rho: 0.02 }, WireCodec::Raw),
        (MethodSpec::TopK { rho: 0.02 }, WireCodec::Entropy),
        (MethodSpec::GSpar { rho: 0.05, iters: 2 }, WireCodec::Raw),
    ] {
        let run = |batch: bool| {
            let mut cluster = Session::builder()
                .method(spec)
                .codec(codec)
                .workers(workers)
                .seed(62)
                .feedback(FeedbackConfig::default())
                .batch_layers(batch)
                .build()
                .cluster(&dims);
            let rounds: Vec<_> = (0..3).map(|_| cluster.round(&grads)).collect();
            (rounds, cluster.frames_received())
        };
        let (per_layer, pl_frames) = run(false);
        let (batched, b_frames) = run(true);
        for (r, (pl_round, b_round)) in per_layer.iter().zip(&batched).enumerate() {
            for (l, (a, b)) in pl_round.iter().zip(b_round).enumerate() {
                assert_eq!(
                    a.grad, b.grad,
                    "{spec:?}/{codec}: round {r} layer {l} drifted under batching"
                );
            }
        }
        assert!(
            b_frames < pl_frames,
            "{spec:?}/{codec}: batching must ship fewer frames"
        );
    }
}

// ---------------------------------------------------------------------------
// Composition: feedback + local steps on the aggressive regime end to end.
// ---------------------------------------------------------------------------

#[test]
fn qsparse_style_composition_converges() {
    // Qsparse-local-SGD's composition — biased top-k, error feedback, and
    // H = 4 local steps — on the sync trainer: communication drops ~4× on
    // top of the 30× sparsification and the run still optimizes.
    let ds = gen_logistic(256, 512, 0.6, 0.25, 29);
    let model = LogisticModel::new(1.0 / (10.0 * 256.0));
    let task = SyncTask {
        batch: 8,
        epochs: 60, // 8 rounds/epoch → 480 rounds
        lr: 1.0,
        opt: OptKind::SgdInvT,
        ..SyncTask::default()
    };
    let curve = Session::builder()
        .method(MethodSpec::TopK { rho: 0.03 })
        .feedback(FeedbackConfig::default())
        .local_steps(4)
        .workers(4)
        .seed(29)
        .build()
        .train_convex(&task, &ds, &model);
    let f0 = model.loss(&ds, &vec![0.0; 512]);
    assert!(
        curve.final_loss() < f0 * 0.8,
        "{f0} -> {}",
        curve.final_loss()
    );
    // 480 rounds at H = 4 → 120 comm rounds × 4 workers.
    assert_eq!(curve.ledger.messages, 120 * 4);
}

/// Shared-suite hook for the CI feedback matrix: the plain sync pipeline
/// must behave under `GSPARSE_FEEDBACK=on` exactly as it does off — same
/// byte accounting structure, convergence intact — with the residual
/// memory wrapped around every worker.
#[test]
fn sync_pipeline_runs_under_env_feedback_toggle() {
    let ds = gen_logistic(128, 256, 0.6, 0.25, 7);
    let model = LogisticModel::new(1.0 / (10.0 * 128.0));
    let task = SyncTask {
        batch: 8,
        epochs: 12,
        lr: 1.0,
        ..SyncTask::default()
    };
    let mut builder = Session::builder()
        .method(MethodSpec::GSpar { rho: 0.1, iters: 2 })
        .workers(4)
        .seed(7);
    if let Some(cfg) = FeedbackConfig::from_env() {
        builder = builder.feedback(cfg);
    }
    let curve = builder.build().train_convex(&task, &ds, &model);
    let first = curve.points.first().unwrap().loss;
    assert!(curve.final_loss() < first * 0.9);
    assert!(curve.ledger.wire_bytes > 0);
    assert!(curve.ledger.measured_frames > 0);
}
