//! Acceptance tests of the pipelined compression↔network overlap path
//! (this PR's headline criteria):
//!
//! * a [`Session`] running the threaded cluster at pipeline depth ∈ {2, 4}
//!   produces **bitwise-identical** decoded updates, wire bytes, and frame
//!   counts to the depth-1 sequential reference — under both codecs, with
//!   and without error feedback (property-tested over random layer lists);
//! * a streaming-encoded `WireBatch` that is truncated mid-chunk or
//!   carries a length mismatch is rejected, never misread;
//! * the vectored (zero-copy) frame write path is byte-identical on the
//!   receiving socket to the scratch-buffer path it replaces;
//! * a pipelined TCP dist run interoperates with the stock (sequential)
//!   v3 server bitwise — pipelining reorders work, never bytes.

use gsparse::api::{MethodSpec, Session};
use gsparse::coding::{self, BatchStreamEncoder, WireCodec};
use gsparse::coordinator::dist::{self, RunPlan};
use gsparse::feedback::FeedbackConfig;
use gsparse::rngkit::RandArray;
use gsparse::sparsify::{greedy_probs, sample_sparse, SparseGrad};
use gsparse::transport::frame::{self, GradHeader};
use gsparse::transport::{Connection, Hello, InProcTransport, Listener, TcpTransport, Transport};

/// One threaded-cluster round set at the given depth; returns everything
/// the parity criteria compare.
fn cluster_round(
    dims: &[usize],
    grads: &[Vec<Vec<f32>>],
    codec: WireCodec,
    feedback: bool,
    depth: usize,
    rounds: usize,
) -> (Vec<Vec<Vec<f32>>>, u64, u64, u64) {
    let mut builder = Session::builder()
        .method(MethodSpec::GSpar { rho: 0.05, iters: 2 })
        .codec(codec)
        .workers(grads.len())
        .seed(4021)
        .batch_layers(true)
        .pipeline(depth);
    if feedback {
        builder = builder.feedback(FeedbackConfig::default());
    }
    let mut cluster = builder.build().cluster(dims);
    let mut updates = Vec::new();
    for _ in 0..rounds {
        let upd = cluster.round(grads);
        updates.push(upd.iter().map(|u| u.grad.clone()).collect());
    }
    (
        updates,
        cluster.ledger.wire_bytes,
        cluster.ledger.measured_bytes,
        cluster.frames_received(),
    )
}

#[test]
fn property_pipelined_cluster_rounds_are_bitwise_identical() {
    // The headline parity matrix: codec × feedback × depth ∈ {2, 4}, over
    // random layer lists — every cell must match the depth-1 reference in
    // decoded updates, wire bytes, and frame counts, bit for bit.
    gsparse::proptest_lite::run("pipelined cluster parity", 10, |gen| {
        let nlayers = gen.usize_in(2, 5);
        let dims: Vec<usize> = (0..nlayers).map(|_| gen.usize_in(1, 1800)).collect();
        let workers = 2;
        let seed = gen.u64();
        let grads: Vec<Vec<Vec<f32>>> = (0..workers)
            .map(|w| {
                dims.iter()
                    .enumerate()
                    .map(|(l, &d)| {
                        gsparse::benchkit::skewed_gradient(
                            d,
                            seed ^ (w * 31 + l) as u64,
                            0.2,
                        )
                    })
                    .collect()
            })
            .collect();
        for codec in [WireCodec::Raw, WireCodec::Entropy] {
            for feedback in [false, true] {
                let reference = cluster_round(&dims, &grads, codec, feedback, 1, 2);
                for depth in [2usize, 4] {
                    let piped = cluster_round(&dims, &grads, codec, feedback, depth, 2);
                    if piped.0 != reference.0 {
                        return Err(format!(
                            "{codec}/feedback={feedback}: depth {depth} updates drifted"
                        ));
                    }
                    if (piped.1, piped.2, piped.3) != (reference.1, reference.2, reference.3)
                    {
                        return Err(format!(
                            "{codec}/feedback={feedback}: depth {depth} ledger drifted \
                             ({:?} vs {:?})",
                            (piped.1, piped.2, piped.3),
                            (reference.1, reference.2, reference.3)
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

fn sample_layer(d: usize, rho: f32, seed: u64) -> SparseGrad {
    let g = gsparse::benchkit::skewed_gradient(d, seed, 0.3);
    let mut p = Vec::new();
    let pv = greedy_probs(&g, rho, 2, &mut p);
    let mut ra = RandArray::from_seed(seed ^ 0x5151, 1 << 16);
    sample_sparse(&g, &p, pv.inv_lambda, &mut ra)
}

#[test]
fn hostile_streamed_batches_are_rejected_not_misread() {
    // Glue a streaming-encoded batch by hand (header ++ segments), then
    // attack it the way a broken pipelined sender would: cut mid-chunk, cut
    // on a chunk boundary, leave trailing bytes. Every mutation must fail
    // decode; the intact glue must decode to the planned layers.
    let layers = vec![
        sample_layer(4096, 0.02, 1),
        SparseGrad::empty(64),
        sample_layer(2048, 0.05, 2),
    ];
    let refs: Vec<&SparseGrad> = layers.iter().collect();
    for codec in [WireCodec::Raw, WireCodec::Entropy] {
        let mut enc = BatchStreamEncoder::plan(&refs, codec);
        let mut buf = enc.header().to_vec();
        let mut chunk_ends = Vec::new();
        let mut seg = Vec::new();
        for sg in &layers {
            enc.encode_next(sg, &mut seg);
            buf.extend_from_slice(&seg);
            chunk_ends.push(buf.len());
        }
        assert!(enc.is_done());
        assert_eq!(buf.len(), enc.total_len(), "{codec}: planned length drifted");

        let mut out = Vec::new();
        let mut lens = Vec::new();
        coding::decode_batch_into(&buf, &mut out, &mut lens)
            .unwrap_or_else(|e| panic!("{codec}: intact stream undecodable: {e}"));
        assert_eq!(out, layers, "{codec}: streamed glue decoded wrong");

        // Truncated mid-chunk: cut inside the second layer's segment.
        let mid = (chunk_ends[0] + chunk_ends[1]) / 2;
        assert!(
            coding::decode_batch_into(&buf[..mid], &mut out, &mut lens).is_err(),
            "{codec}: mid-chunk truncation accepted"
        );
        // Truncated exactly on a chunk boundary: the header still claims
        // three layers, so a two-chunk prefix is a length error, not a
        // shorter batch.
        assert!(
            coding::decode_batch_into(&buf[..chunk_ends[1]], &mut out, &mut lens).is_err(),
            "{codec}: chunk-boundary truncation accepted"
        );
        // Length mismatch: trailing bytes after the final chunk.
        let mut long = buf.clone();
        long.push(0);
        assert!(
            matches!(
                coding::decode_batch_into(&long, &mut out, &mut lens),
                Err(coding::WireError::LengthMismatch { .. })
            ),
            "{codec}: trailing bytes accepted"
        );
        // A hostile sub-header length claim: the first layer's nnz_b set
        // past its dimension must be rejected at the header gate, before
        // any chunk payload is interpreted.
        let mut bad = buf.clone();
        let nb_at = coding::BATCH_HEADER_LEN + 9;
        bad[nb_at..nb_at + 4].copy_from_slice(&4097u32.to_le_bytes());
        assert!(
            matches!(
                coding::decode_batch_into(&bad, &mut out, &mut lens),
                Err(coding::WireError::CountsExceedDim { .. })
            ),
            "{codec}: hostile sub-header count accepted"
        );
    }
}

/// One established TCP link pair.
fn tcp_pair() -> (Box<dyn Connection>, Box<dyn Connection>) {
    let t = TcpTransport::new();
    let mut listener = t.listen("127.0.0.1:0").unwrap();
    let addr = listener.local_addr();
    let client = std::thread::spawn(move || t.connect(&addr, &Hello::new(0)).unwrap());
    let (server, hello) = listener.accept().unwrap();
    assert_eq!(hello.worker_id, 0);
    (client.join().unwrap(), server)
}

#[test]
fn vectored_grad_frames_arrive_byte_identical_over_tcp() {
    // The zero-copy write path end to end: a GRAD frame sent as
    // [header-prefix, payload] gather segments must arrive byte-identical
    // to the same frame sent through the scratch-copy path — and a
    // streamed GRAD_BATCH sent as [prefix, batch header, chunk…] must
    // match its one-shot encoding. The receiver cannot tell the paths
    // apart; only the sender's copy counter moves.
    let (mut client, mut server) = tcp_pair();
    let mut buf = Vec::new();

    let header = GradHeader {
        based_on: 3,
        g_norm_sq: 2.0,
        q_norm_sq: 1.5,
        expected_nnz: 40.0,
        ideal_bits: 777,
        kind: 0,
    };
    let payload = {
        let sg = sample_layer(2048, 0.05, 9);
        let mut p = Vec::new();
        coding::encode_with(&sg, WireCodec::Entropy, &mut p);
        p
    };
    // Reference: the scratch-copy spelling.
    let mut whole = Vec::new();
    frame::encode_grad(&mut whole, &header, &payload);
    client.send(&whole).unwrap();
    server.recv(&mut buf).unwrap();
    assert_eq!(buf, whole);

    // Vectored spelling of the same frame.
    let before = client.counters().frames_vectored();
    let mut prefix = Vec::new();
    frame::encode_grad_prefix(&mut prefix, &header);
    client.send_vectored(&[&prefix, &payload]).unwrap();
    server.recv(&mut buf).unwrap();
    assert_eq!(buf, whole, "vectored GRAD frame drifted on the wire");
    assert_eq!(client.counters().frames_vectored(), before + 1);

    // Streamed GRAD_BATCH: prefix + batch header + per-layer chunks.
    let layers = vec![sample_layer(4096, 0.02, 10), sample_layer(1024, 0.1, 11)];
    let refs: Vec<&SparseGrad> = layers.iter().collect();
    let mut batch = Vec::new();
    coding::encode_batch(&refs, WireCodec::Entropy, &mut batch);
    let mut whole_batch = Vec::new();
    frame::encode_grad_batch(&mut whole_batch, &header, &batch);

    let mut enc = BatchStreamEncoder::plan(&refs, WireCodec::Entropy);
    let mut bprefix = Vec::new();
    frame::encode_grad_batch_prefix(&mut bprefix, &header);
    let mut chunks: Vec<Vec<u8>> = Vec::new();
    let mut seg = Vec::new();
    for sg in &layers {
        enc.encode_next(sg, &mut seg);
        chunks.push(seg.clone());
    }
    let mut segments: Vec<&[u8]> = vec![&bprefix, enc.header()];
    segments.extend(chunks.iter().map(|c| c.as_slice()));
    client.send_vectored(&segments).unwrap();
    server.recv(&mut buf).unwrap();
    assert_eq!(buf, whole_batch, "streamed GRAD_BATCH frame drifted on the wire");
}

#[test]
fn pipelined_tcp_dist_runs_interoperate_with_sequential_peers_bitwise() {
    // The interop criterion: a pipelined sender is indistinguishable on the
    // wire from a sequential one, so a depth-2 run over real TCP must match
    // the depth-1 run — and the InProc reference — in gradient digests,
    // final weights, and the measured byte/frame ledger. The server side is
    // the stock v3 receiver in both runs; it is never told about depths.
    let base = || RunPlan {
        workers: 2,
        rounds: 40,
        n: 128,
        d: 64,
        batch: 4,
        seed: 91,
        reg: 1.0 / (10.0 * 128.0),
        codec: WireCodec::Entropy,
        ..Default::default()
    };
    let seq = RunPlan { pipeline: 1, ..base() };
    let pipe = RunPlan { pipeline: 2, ..base() };
    let seq_rep = dist::run_threads(TcpTransport::new(), "127.0.0.1:0", &seq).unwrap();
    let pipe_rep = dist::run_threads(TcpTransport::new(), "127.0.0.1:0", &pipe).unwrap();
    let inproc_rep = dist::run_threads(InProcTransport::new(), "pipe-interop", &pipe).unwrap();

    assert_eq!(pipe_rep.grad_digest, seq_rep.grad_digest);
    assert_eq!(pipe_rep.final_w, seq_rep.final_w);
    assert_eq!(
        pipe_rep.curve.ledger.measured_bytes,
        seq_rep.curve.ledger.measured_bytes,
        "pipelining must not change a single framed byte"
    );
    assert_eq!(
        pipe_rep.curve.ledger.measured_frames,
        seq_rep.curve.ledger.measured_frames
    );
    assert_eq!(pipe_rep.grad_digest, inproc_rep.grad_digest);
    assert_eq!(pipe_rep.final_w, inproc_rep.final_w);

    // And a pipelined sender facing a version-2 peer link: the v2 hello
    // downgrades batching, not correctness — the run still matches the
    // sequential reference bitwise.
    let t = TcpTransport::new();
    let mut listener = t.listen("127.0.0.1:0").unwrap();
    let addr = listener.local_addr();
    let mut handles = Vec::new();
    for wid in 0..pipe.workers {
        let t = TcpTransport::new();
        let addr = addr.clone();
        let codec = pipe.codec;
        handles.push(std::thread::spawn(move || {
            let hello = Hello::with_version(wid as u32, codec, 2);
            let mut conn = t.connect(&addr, &hello).unwrap();
            dist::run_worker(conn.as_mut(), wid as u32, codec, 2, None)
        }));
    }
    let v2_rep = dist::serve(listener.as_mut(), &pipe).unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    assert_eq!(v2_rep.grad_digest, seq_rep.grad_digest);
    assert_eq!(v2_rep.final_w, seq_rep.final_w);
}
