//! Integration tests for the sparse ring collective: cross-backend bitwise
//! parity (the same reduction over in-process channels and real loopback
//! TCP sockets), sum-correctness properties for both reduction arms, and
//! the paper-scale byte advantage the ring schedule exists for.

use gsparse::coding::{self, WireCodec};
use gsparse::collective::{self, AlignedConfig, RingReducer};
use gsparse::comm::Topology;
use gsparse::config::Method;
use gsparse::coordinator::dist::{self, RunPlan};
use gsparse::proptest_lite::{run, Gen};
use gsparse::rngkit::Xoshiro256pp;
use gsparse::sparsify::SparseGrad;
use gsparse::transport::{InProcTransport, LinkCounters, TcpTransport, Transport};

/// Deterministic sparse vector with ~`k` strictly-ascending entries and
/// integer-valued coordinates (sums of a few of them are exact in f32, so
/// order-of-summation cannot blur equality assertions).
fn integer_sparse(d: usize, k: usize, seed: u64) -> SparseGrad {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut sg = SparseGrad::empty(d);
    let stride = (d / k.max(1)).max(1) as u64;
    let mut idx = rng.next_below(stride) as usize;
    while idx < d && sg.exact.len() < k {
        let mut v = (rng.next_below(15) as f32) - 7.0;
        if v == 0.0 {
            v = 1.0;
        }
        sg.exact.push((idx as u32, v));
        idx += 1 + rng.next_below(2 * stride) as usize;
    }
    sg
}

/// Run one full ring reduction — every rank on its own thread — and return
/// each rank's reduced result re-encoded to bytes (the bitwise identity
/// the tests compare across ranks and backends).
fn reduce_on(
    transport: &dyn Transport,
    binds: &[String],
    inputs: &[SparseGrad],
    budget: Option<usize>,
    aligned: Option<AlignedConfig>,
) -> Vec<Vec<u8>> {
    let m = inputs.len();
    let peers = collective::form_ring_local(transport, m, WireCodec::Raw, binds).unwrap();
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(m);
        for (mut peer, input) in peers.into_iter().zip(inputs) {
            handles.push(scope.spawn(move || {
                let mut reducer = RingReducer::new(WireCodec::Raw, budget);
                let mut out = SparseGrad::empty(0);
                match aligned {
                    Some(cfg) => reducer
                        .reduce_aligned(&mut peer, &cfg, input, &mut out, None)
                        .unwrap(),
                    None => reducer.reduce(&mut peer, input, &mut out, None).unwrap(),
                };
                let mut bytes = Vec::new();
                coding::encode_with(&out, WireCodec::Raw, &mut bytes);
                bytes
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

#[test]
fn ring_reduce_is_bitwise_identical_across_backends() {
    let m = 4usize;
    let d = 4096usize;
    let inputs: Vec<SparseGrad> = (0..m)
        .map(|w| integer_sparse(d, 200, 0xC0FFEE ^ w as u64))
        .collect();
    let budget = Some(collective::default_budget(0.05, d as u32, m));

    let inproc = InProcTransport::new();
    let in_binds: Vec<String> = (0..m).map(|r| format!("parity-{r}")).collect();
    let in_results = reduce_on(&inproc, &in_binds, &inputs, budget, None);

    let tcp = TcpTransport::new();
    let tcp_binds: Vec<String> = (0..m).map(|_| "127.0.0.1:0".to_string()).collect();
    let tcp_results = reduce_on(&tcp, &tcp_binds, &inputs, budget, None);

    // Every rank holds the identical reduced message, and the channel
    // backend leaves no fingerprint on it.
    for r in 1..m {
        assert_eq!(in_results[0], in_results[r], "rank {r} drifted (inproc)");
        assert_eq!(tcp_results[0], tcp_results[r], "rank {r} drifted (tcp)");
    }
    assert_eq!(in_results[0], tcp_results[0], "backends disagree");
    assert!(!in_results[0].is_empty());
}

#[test]
fn dist_ring_runs_are_bitwise_identical_across_backends() {
    // The whole dist coordinator under ring topology: threads over
    // in-process channels vs threads over loopback TCP must produce the
    // same gradient digest and final weights.
    let cfg = RunPlan {
        workers: 3,
        rounds: 20,
        method: Method::TopK,
        rho: 0.1,
        n: 128,
        d: 96,
        batch: 4,
        seed: 7,
        topology: Topology::Ring,
        ..Default::default()
    };
    let a = dist::run_threads(InProcTransport::new(), "col-ring", &cfg).unwrap();
    let b = dist::run_threads(TcpTransport::new(), "127.0.0.1:0", &cfg).unwrap();
    assert_eq!(a.grad_digest, b.grad_digest);
    assert_eq!(a.final_w, b.final_w);
    assert_eq!(a.versions, b.versions);
}

#[test]
fn prop_unbudgeted_ring_reduce_equals_dense_sum() {
    run("unbudgeted ring reduce equals the dense sum", 12, |g: &mut Gen| {
        let m = g.usize_in(2, 4);
        let d = g.usize_in(8, 400);
        let salt = g.u64();
        let inputs: Vec<SparseGrad> = (0..m)
            .map(|w| integer_sparse(d, 1 + d / 4, salt ^ (w as u64).wrapping_mul(0x9E37)))
            .collect();
        let mut dense = vec![0.0f32; d];
        for sg in &inputs {
            sg.add_into(1.0, &mut dense);
        }
        let transport = InProcTransport::new();
        let binds: Vec<String> = (0..m).map(|r| format!("prop-{r}")).collect();
        let outs = reduce_on(&transport, &binds, &inputs, None, None);
        let mut decoded = SparseGrad::empty(0);
        for bytes in &outs {
            coding::decode_into(bytes, &mut decoded).unwrap();
            let mut got = vec![0.0f32; d];
            decoded.add_into(1.0, &mut got);
            // Integer-valued inputs: the sum is exact whatever the merge
            // order, so equality is bitwise.
            if got != dense {
                return Err(format!("m={m} d={d}: ring sum diverged from dense sum"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_aligned_reduce_reports_exact_sums_on_selected_coords() {
    run("aligned reduce: ≤ k coords, each an exact sum", 8, |g: &mut Gen| {
        let m = g.usize_in(2, 4);
        let d = g.usize_in(16, 300);
        let k = g.usize_in(1, d);
        let salt = g.u64();
        let inputs: Vec<SparseGrad> = (0..m)
            .map(|w| integer_sparse(d, 1 + d / 5, salt ^ (w as u64).wrapping_mul(0xA11)))
            .collect();
        let mut dense = vec![0.0f32; d];
        for sg in &inputs {
            sg.add_into(1.0, &mut dense);
        }
        let cfg = AlignedConfig {
            rows: 3,
            buckets: 256,
            k,
            seed: 0xFACE,
        };
        let transport = InProcTransport::new();
        let binds: Vec<String> = (0..m).map(|r| format!("alp-{r}")).collect();
        let outs = reduce_on(&transport, &binds, &inputs, None, Some(cfg));
        for bytes in &outs {
            if bytes != &outs[0] {
                return Err("aligned ranks disagree bitwise".into());
            }
        }
        let mut decoded = SparseGrad::empty(0);
        coding::decode_into(&outs[0], &mut decoded).unwrap();
        if decoded.exact.len() > k {
            return Err(format!("{} coords > k {k}", decoded.exact.len()));
        }
        // Index-free reduction still carries *exact* sums for whatever the
        // shared sketch selected — estimation only picks coordinates, it
        // never blurs values.
        for &(i, v) in &decoded.exact {
            if v != dense[i as usize] {
                return Err(format!("coord {i}: got {v}, dense {}", dense[i as usize]));
            }
        }
        Ok(())
    });
}

#[test]
fn ring_ships_fewer_per_node_bytes_than_star_at_paper_scale() {
    // The acceptance scale: M = 16, d = 2^20, ρ = 0.01. Star all-reduce
    // per-node traffic is the uploaded message plus the downloaded merged
    // sum (~M·ρd entries); the budgeted ring caps every hop at ⌈2ρd/M⌉
    // entries across 2(M−1) hops. Both sides are *measured* on real
    // transport links, not modeled.
    let m = 16usize;
    let d = 1usize << 20;
    let rho = 0.01f32;
    let k = (rho * d as f32) as usize;
    let inputs: Vec<SparseGrad> = (0..m)
        .map(|w| integer_sparse(d, k, 0xBEEF ^ w as u64))
        .collect();

    // Ring: per-node cost = that rank's right-link transmitted bytes.
    let transport = InProcTransport::new();
    let binds: Vec<String> = (0..m).map(|r| format!("scale-{r}")).collect();
    let peers = collective::form_ring_local(&transport, m, WireCodec::Raw, &binds).unwrap();
    let tx: Vec<LinkCounters> = peers.iter().map(|p| p.right_counters()).collect();
    let budget = Some(collective::default_budget(rho, d as u32, m));
    std::thread::scope(|scope| {
        for (mut peer, input) in peers.into_iter().zip(&inputs) {
            scope.spawn(move || {
                let mut reducer = RingReducer::new(WireCodec::Raw, budget);
                let mut out = SparseGrad::empty(0);
                reducer.reduce(&mut peer, input, &mut out, None).unwrap();
            });
        }
    });
    let ring_per_node_max = tx.iter().map(|c| c.bytes_tx()).max().unwrap();

    // Star all-reduce over the same transport: every worker uploads its
    // message to a hub and downloads the merged sum.
    let hub_t = InProcTransport::new();
    let mut listener = hub_t.listen("scale-hub").unwrap();
    let worker_counters: Vec<LinkCounters> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(m);
        for (w, input) in inputs.iter().enumerate() {
            let t = &hub_t;
            handles.push(scope.spawn(move || {
                let mut conn = t
                    .connect(
                        "scale-hub",
                        &gsparse::transport::Hello::with_codec(w as u32, WireCodec::Raw),
                    )
                    .unwrap();
                let mut bytes = Vec::new();
                coding::encode_with(input, WireCodec::Raw, &mut bytes);
                conn.send(&bytes).unwrap();
                let mut rx = Vec::new();
                conn.recv(&mut rx).unwrap();
                conn.counters()
            }));
        }
        let accepted =
            gsparse::transport::accept_n_hello(listener.as_mut(), m, WireCodec::Raw).unwrap();
        let mut sum = SparseGrad::empty(d);
        let mut incoming = SparseGrad::empty(0);
        let mut merged = SparseGrad::empty(0);
        let mut rx = Vec::new();
        let mut conns: Vec<_> = accepted.into_iter().map(|(c, _)| c).collect();
        for conn in conns.iter_mut() {
            conn.recv(&mut rx).unwrap();
            coding::decode_into(&rx, &mut incoming).unwrap();
            gsparse::comm::merge::merge_sum(&sum, &incoming, &mut merged);
            std::mem::swap(&mut sum, &mut merged);
        }
        let mut down = Vec::new();
        coding::encode_with(&sum, WireCodec::Raw, &mut down);
        for conn in conns.iter_mut() {
            conn.send(&down).unwrap();
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let star_per_node_min = worker_counters
        .iter()
        .map(|c| c.bytes_total())
        .min()
        .unwrap();

    assert!(
        ring_per_node_max < star_per_node_min,
        "ring per-node {ring_per_node_max} B must beat star per-node {star_per_node_min} B \
         at M={m}, d={d}, rho={rho}"
    );
}
