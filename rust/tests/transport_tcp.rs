//! Transport parity and multi-process integration tests — the acceptance
//! criteria of the distributed runtime:
//!
//! * the same parameter-server run over loopback **TCP** and over the
//!   in-process channel backend ships bitwise-identical compressed
//!   gradients, reaches bitwise-identical weights, and reports identical
//!   byte ledgers (the InProc backend frames and counts exactly like TCP);
//! * a cluster of one server + two genuine **worker OS processes**
//!   (spawned from the `gsparse` binary) matches the in-process run too,
//!   and reports measured socket bytes;
//! * the frame codec survives empty, large, and corrupted frames over real
//!   sockets.

use gsparse::coding::WireCodec;
use gsparse::coordinator::dist::{self, RunPlan};
use gsparse::data::gen_logistic;
use gsparse::model::LogisticModel;
use gsparse::transport::frame::{self, MsgView};
use gsparse::transport::{
    Connection, Hello, InProcTransport, Listener, TcpTransport, Transport, TransportError,
};

/// The shared suite honours the CI `codec: [raw, entropy]` matrix via
/// `GSPARSE_CODEC`, the `feedback: [off, on]` matrix via
/// `GSPARSE_FEEDBACK` (error feedback rides the CONFIG frame, so the
/// parity criteria must hold with the residual memory engaged too), and
/// the `pipeline: [1, 2]` matrix via `GSPARSE_PIPELINE` (depth ≥ 2 sends
/// gradients as vectored header+payload segments — same bytes, different
/// write path); the explicit `*_entropy_codec` tests below pin the entropy
/// variant regardless of the environment.
fn test_cfg() -> RunPlan {
    RunPlan {
        workers: 2,
        rounds: 150,
        n: 256,
        d: 128,
        batch: 8,
        seed: 71,
        reg: 1.0 / (10.0 * 256.0),
        codec: WireCodec::from_env(),
        feedback: gsparse::feedback::FeedbackConfig::from_env(),
        pipeline: gsparse::api::pipeline_from_env(),
        ..Default::default()
    }
}

fn entropy_cfg() -> RunPlan {
    RunPlan {
        codec: WireCodec::Entropy,
        ..test_cfg()
    }
}

fn assert_backend_parity(cfg: &RunPlan) {
    let inproc = dist::run_threads(InProcTransport::new(), "parity", cfg).unwrap();
    let tcp = dist::run_threads(TcpTransport::new(), "127.0.0.1:0", cfg).unwrap();

    // Identical compressed gradient bytes, in apply order.
    assert_eq!(tcp.grad_digest, inproc.grad_digest);
    // Identical final weights, bitwise.
    assert_eq!(tcp.final_w, inproc.final_w);
    assert_eq!(tcp.final_loss, inproc.final_loss);
    // Identical byte ledgers — including the measured column, because the
    // InProc backend frames (and counts) exactly like the TCP backend.
    let (a, b) = (&inproc.curve.ledger, &tcp.curve.ledger);
    assert_eq!(a.ideal_bits, b.ideal_bits);
    assert_eq!(a.wire_bytes, b.wire_bytes);
    assert_eq!(a.wire_bytes_by_codec, b.wire_bytes_by_codec);
    assert_eq!(a.measured_bytes, b.measured_bytes);
    assert_eq!(a.messages, b.messages);
    // And the loss curves agree point-for-point.
    assert_eq!(inproc.curve.points.len(), tcp.curve.points.len());
    for (p, q) in inproc.curve.points.iter().zip(&tcp.curve.points) {
        assert_eq!(p.loss, q.loss);
        assert_eq!(p.comm_bits, q.comm_bits);
    }
}

#[test]
fn tcp_backend_matches_inproc_bitwise() {
    assert_backend_parity(&test_cfg());
}

#[test]
fn tcp_backend_matches_inproc_bitwise_entropy_codec() {
    // The `--codec entropy` variant of the parity criterion: same codec ⇒
    // identical bytes across backends, with every sparse byte ledgered in
    // the entropy column.
    let cfg = entropy_cfg();
    assert_backend_parity(&cfg);
    let rep = dist::run_threads(InProcTransport::new(), "parity-e", &cfg).unwrap();
    assert_eq!(
        rep.curve.ledger.wire_bytes_by_codec[WireCodec::Entropy.index()],
        rep.curve.ledger.wire_bytes
    );
}

#[test]
fn multi_process_cluster_matches_in_process_run() {
    multi_process_parity(&test_cfg());
}

#[test]
fn multi_process_cluster_matches_in_process_run_entropy_codec() {
    // 1 server + 2 worker processes negotiating `--codec entropy` on their
    // real command lines — the smoke test's entropy variant.
    multi_process_parity(&entropy_cfg());
}

#[test]
fn multi_process_cluster_matches_in_process_run_feedback_local_steps() {
    // The feedback-determinism criterion across *OS processes*: residual
    // state lives inside each spawned worker (shipped via the CONFIG
    // frame, never on the wire), yet the compressed bytes and final
    // weights must match the in-process threads run bitwise — with error
    // feedback on a biased method AND a local-step schedule engaged.
    let cfg = RunPlan {
        method: gsparse::config::Method::TopK,
        rho: 0.05,
        rounds: 60,
        local_steps: 3,
        feedback: Some(gsparse::feedback::FeedbackConfig::default()),
        ..test_cfg()
    };
    let bin = std::path::PathBuf::from(env!("CARGO_BIN_EXE_gsparse"));
    let procs = dist::run_processes(&bin, "127.0.0.1:0", &cfg).unwrap();
    let inproc = dist::run_threads(InProcTransport::new(), "mp-fb", &cfg).unwrap();
    assert_eq!(procs.grad_digest, inproc.grad_digest);
    assert_eq!(procs.final_w, inproc.final_w);
    // 60 local rounds at H = 3 → 20 pushes per worker.
    assert_eq!(procs.versions, (20 * cfg.workers) as u64);
    assert_eq!(
        procs.curve.ledger.measured_bytes,
        inproc.curve.ledger.measured_bytes
    );
    assert_eq!(
        procs.curve.ledger.measured_frames,
        inproc.curve.ledger.measured_frames
    );
}

fn multi_process_parity(cfg: &RunPlan) {
    // One server (this test) + two genuine worker OS processes over
    // loopback TCP — the repo's "real multi-process cluster" smoke test.
    let bin = std::path::PathBuf::from(env!("CARGO_BIN_EXE_gsparse"));
    let procs = dist::run_processes(&bin, "127.0.0.1:0", cfg).unwrap();
    let inproc = dist::run_threads(InProcTransport::new(), "mp-ref", cfg).unwrap();

    // Converged at all?
    let ds = gen_logistic(cfg.n, cfg.d, cfg.c1, cfg.c2, cfg.seed);
    let model = LogisticModel::new(cfg.reg);
    let f0 = gsparse::model::ConvexModel::loss(&model, &ds, &vec![0.0; cfg.d]);
    assert!(procs.final_loss < f0 * 0.95, "{f0} -> {}", procs.final_loss);

    // Parity with the in-process backend: same gradient bytes per round,
    // same final loss (bitwise — every arithmetic input is identical).
    assert_eq!(procs.grad_digest, inproc.grad_digest);
    assert_eq!(procs.final_w, inproc.final_w);
    assert!((procs.final_loss - inproc.final_loss).abs() <= f32::EPSILON as f64);
    assert_eq!(procs.versions, (cfg.rounds * cfg.workers) as u64);

    // Measured socket bytes are reported and exceed the raw payloads.
    assert!(procs.measured_rx_bytes > 0);
    assert!(procs.measured_tx_bytes > 0);
    assert_eq!(
        procs.curve.ledger.measured_bytes,
        procs.measured_tx_bytes + procs.measured_rx_bytes
    );
    assert!(procs.curve.ledger.measured_bytes > procs.curve.ledger.wire_bytes);
    assert_eq!(
        procs.curve.ledger.measured_bytes,
        inproc.curve.ledger.measured_bytes
    );
}

/// One established TCP link pair for codec tests.
fn tcp_pair() -> (Box<dyn Connection>, Box<dyn Connection>) {
    let t = TcpTransport::new();
    let mut listener = t.listen("127.0.0.1:0").unwrap();
    let addr = listener.local_addr();
    let client = std::thread::spawn(move || t.connect(&addr, &Hello::new(0)).unwrap());
    let (server, hello) = listener.accept().unwrap();
    assert_eq!(hello.worker_id, 0);
    (client.join().unwrap(), server)
}

#[test]
fn frame_roundtrips_over_tcp_empty_and_large() {
    let (mut client, mut server) = tcp_pair();
    let mut buf = Vec::new();

    // Empty frame.
    client.send(b"").unwrap();
    server.recv(&mut buf).unwrap();
    assert_eq!(buf, b"");

    // Multi-megabyte frame (a dense weights message for d = 1M).
    let w: Vec<f32> = (0..1_000_000).map(|i| i as f32 * 0.5).collect();
    let mut frame_buf = Vec::new();
    frame::encode_weights(&mut frame_buf, 9, &w);
    let sender = {
        let payload = frame_buf.clone();
        std::thread::spawn(move || {
            client.send(&payload).unwrap();
            client
        })
    };
    server.recv(&mut buf).unwrap();
    sender.join().unwrap();
    assert_eq!(buf, frame_buf);
    match frame::decode(&buf).unwrap() {
        MsgView::Weights { version, w_bytes } => {
            assert_eq!(version, 9);
            let mut back = Vec::new();
            frame::weights_into(w_bytes, &mut back);
            assert_eq!(back, w);
        }
        other => panic!("{other:?}"),
    }
}

#[test]
fn weights_batch_frame_roundtrips_over_tcp() {
    // The multi-tensor pull frame end to end over real sockets: one frame
    // carries a whole layer list's weights, and both readers reproduce it.
    let (mut client, mut server) = tcp_pair();
    let tensors: Vec<Vec<f32>> = vec![
        (0..1000).map(|i| i as f32 * 0.25).collect(),
        vec![],
        (0..37).map(|i| -(i as f32)).collect(),
    ];
    let refs: Vec<&[f32]> = tensors.iter().map(|t| t.as_slice()).collect();
    let mut frame_buf = Vec::new();
    frame::encode_weights_batch(&mut frame_buf, 5, &refs);
    client.send(&frame_buf).unwrap();
    let mut buf = Vec::new();
    server.recv(&mut buf).unwrap();
    match frame::decode(&buf).unwrap() {
        MsgView::WeightsBatch { version, batch } => {
            assert_eq!(version, 5);
            assert_eq!(frame::weights_batch_count(batch), 3);
            let mut segs = Vec::new();
            frame::weights_batch_segments_into(batch, &mut segs);
            assert_eq!(segs, tensors);
            let mut flat = Vec::new();
            frame::weights_batch_into(batch, &mut flat);
            assert_eq!(flat.len(), 1037);
        }
        other => panic!("{other:?}"),
    }
}

#[test]
fn property_random_frames_roundtrip_over_tcp() {
    let (mut client, mut server) = tcp_pair();
    let mut buf = Vec::new();
    gsparse::proptest_lite::run("tcp frame roundtrip", 64, |gen| {
        let len = gen.usize_in(0, 1 << 16);
        let payload: Vec<u8> = (0..len).map(|_| gen.u64() as u8).collect();
        client.send(&payload).map_err(|e| e.to_string())?;
        server.recv(&mut buf).map_err(|e| e.to_string())?;
        if buf == payload {
            Ok(())
        } else {
            Err(format!("frame of {len} bytes corrupted in transit"))
        }
    });
}

#[test]
fn server_rejects_corrupted_gradient_frames() {
    // A worker that completes the handshake + config exchange, then ships
    // a gradient whose codec payload is garbage: the server must fail with
    // a decode error (the hardened `coding::decode_into` path), not panic
    // or apply junk.
    let cfg = RunPlan {
        workers: 1,
        rounds: 5,
        n: 64,
        d: 32,
        ..Default::default()
    };
    let t = TcpTransport::new();
    let mut listener = t.listen("127.0.0.1:0").unwrap();
    let addr = listener.local_addr();
    let evil = std::thread::spawn(move || {
        let mut conn = t.connect(&addr, &Hello::new(0)).unwrap();
        let mut buf = Vec::new();
        conn.recv(&mut buf).unwrap(); // config
        assert!(matches!(
            frame::decode(&buf).unwrap(),
            MsgView::Config { .. }
        ));
        let mut tx = Vec::new();
        frame::encode_pull(&mut tx);
        conn.send(&tx).unwrap();
        conn.recv(&mut buf).unwrap(); // weights
        let header = frame::GradHeader {
            based_on: 0,
            g_norm_sq: 1.0,
            q_norm_sq: 1.0,
            expected_nnz: 1.0,
            ideal_bits: 8,
            kind: 0,
        };
        frame::encode_grad(&mut tx, &header, b"GSPRjunk-not-a-valid-message");
        conn.send(&tx).unwrap();
        // Server will error out and drop the link; further recv fails.
        let _ = conn.recv(&mut buf);
    });
    let err = dist::serve(listener.as_mut(), &cfg).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("version") || msg.contains("magic") || msg.contains("length"),
        "expected a wire decode error, got: {msg}"
    );
    evil.join().unwrap();
}

#[test]
fn server_refuses_codec_mismatched_worker() {
    // An entropy-codec server must refuse a raw-codec hello during accept,
    // before any config or gradient flows — "negotiated like the version
    // field".
    let cfg = RunPlan {
        workers: 1,
        rounds: 3,
        n: 64,
        d: 32,
        codec: WireCodec::Entropy,
        ..Default::default()
    };
    let t = TcpTransport::new();
    let mut listener = t.listen("127.0.0.1:0").unwrap();
    let addr = listener.local_addr();
    let stale = std::thread::spawn(move || {
        let mut conn = t.connect(&addr, &Hello::new(0)).unwrap(); // raw hello
        let mut buf = Vec::new();
        let _ = conn.recv(&mut buf); // server drops the link
    });
    let err = dist::serve(listener.as_mut(), &cfg).unwrap_err();
    assert!(
        format!("{err:#}").contains("codec mismatch"),
        "expected codec mismatch, got: {err:#}"
    );
    stale.join().unwrap();
}

#[test]
fn v2_workers_interoperate_with_a_v4_server_bitwise() {
    // The version-fallback handshake: a server running the version-4
    // transport must accept version-2 hellos (same 10-byte layout, no
    // batch capability) and drive the run to bitwise-identical results —
    // v2 links simply never see `GRAD_BATCH` frames, clock probes, or
    // trace-context stamps. A pre-codec (v1) hello is still refused.
    let cfg = RunPlan {
        workers: 2,
        rounds: 40,
        n: 128,
        d: 64,
        batch: 4,
        seed: 91,
        reg: 1.0 / (10.0 * 128.0),
        ..Default::default()
    };
    let t = TcpTransport::new();
    let mut listener = t.listen("127.0.0.1:0").unwrap();
    let addr = listener.local_addr();
    let mut handles = Vec::new();
    for wid in 0..cfg.workers {
        let t = TcpTransport::new();
        let addr = addr.clone();
        let codec = cfg.codec;
        handles.push(std::thread::spawn(move || {
            // Impersonate an old peer: same frames, version byte 2.
            let hello = Hello::with_version(wid as u32, codec, 2);
            assert_eq!(hello.version, 2);
            assert!(!hello.supports_batch());
            let mut conn = t.connect(&addr, &hello).unwrap();
            dist::run_worker(conn.as_mut(), wid as u32, codec, 2, None)
        }));
    }
    let v2_report = dist::serve(listener.as_mut(), &cfg).unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    // Reference run with current-version workers.
    let v4_report = dist::run_threads(TcpTransport::new(), "127.0.0.1:0", &cfg).unwrap();
    assert_eq!(v2_report.grad_digest, v4_report.grad_digest);
    assert_eq!(v2_report.final_w, v4_report.final_w);
    // A v2 link carries exactly the pre-v4 byte stream: no clock probes,
    // no trace-context stamps. Pin the legacy frame count (hello + config
    // + (blocks+1) pulls + blocks weights + blocks grads + shutdown per
    // link) and check the v4 run's extra telemetry bytes are visible.
    let blocks = cfg.rounds as u64;
    assert_eq!(
        v2_report.curve.ledger.measured_frames,
        (3 * blocks + 4) * cfg.workers as u64,
        "v2 links must not carry probe frames"
    );
    assert!(
        v2_report.curve.ledger.measured_bytes < v4_report.curve.ledger.measured_bytes,
        "v4 links add probe + trace-context bytes: v2 {} !< v4 {}",
        v2_report.curve.ledger.measured_bytes,
        v4_report.curve.ledger.measured_bytes
    );
    // The payload (wire) bytes are identical — telemetry rides only in
    // framing, never in the gradient encoding.
    assert_eq!(
        v2_report.curve.ledger.wire_bytes,
        v4_report.curve.ledger.wire_bytes
    );

    // v1 peers (9-byte hello, version 1) are refused at accept.
    let mut listener = t.listen("127.0.0.1:0").unwrap();
    let addr = listener.local_addr();
    let stale = std::thread::spawn(move || {
        use std::io::Write;
        let mut sock = std::net::TcpStream::connect(addr).unwrap();
        let mut hello = Vec::new();
        hello.extend_from_slice(b"GSTP");
        hello.push(1); // version 1
        hello.extend_from_slice(&0u32.to_le_bytes());
        let mut framed = Vec::new();
        framed.extend_from_slice(&(hello.len() as u32).to_le_bytes());
        framed.extend_from_slice(&hello);
        sock.write_all(&framed).unwrap();
        // Server drops the link after refusing the handshake.
        let _ = sock.shutdown(std::net::Shutdown::Both);
    });
    assert!(matches!(
        listener.accept(),
        Err(TransportError::VersionMismatch { ours: 4, theirs: 1 })
    ));
    stale.join().unwrap();
}

#[test]
fn oversized_frames_are_refused_not_allocated() {
    let (mut client, mut server) = tcp_pair();
    // A frame larger than the cap must be refused on the send side…
    let too_big = vec![0u8; gsparse::transport::MAX_FRAME_LEN + 1];
    assert!(matches!(
        client.send(&too_big),
        Err(TransportError::FrameTooLarge(_))
    ));
    // …and a normal frame still flows afterwards.
    client.send(b"still alive").unwrap();
    let mut buf = Vec::new();
    server.recv(&mut buf).unwrap();
    assert_eq!(buf, b"still alive");
}
