//! Integration tests over the real AOT artifacts (`make artifacts` first).
//!
//! These are the cross-layer contracts: the Rust hot-path implementations
//! (greedy probabilities, logistic gradients) must agree numerically with
//! the JAX/Pallas artifacts executed via PJRT, and the HLO-backed models
//! must compose with the coordinator.

use gsparse::model::hlo::HloTrainStep;
use gsparse::model::ConvexModel;
use gsparse::runtime::{lit, Runtime};

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(
        Runtime::cpu()
            .expect("PJRT CPU client")
            .with_artifact_dir(dir)
            .expect("artifact dir"),
    )
}

fn rng_grad(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = gsparse::rngkit::Xoshiro256pp::seed_from_u64(seed);
    (0..d)
        .map(|_| {
            let u = rng.next_f32();
            if u < 0.1 {
                (rng.next_gaussian() * 4.0) as f32
            } else {
                (rng.next_gaussian() * 0.05) as f32
            }
        })
        .collect()
}

#[test]
fn greedy_probs_artifact_matches_rust() {
    let Some(mut rt) = runtime() else { return };
    let d = 2048;
    let g = rng_grad(d, 1);
    let exe = rt.get("greedy_probs").expect("artifact");
    let outs = exe
        .run_f32(&[lit::f32_tensor(&g, &[d as i64]).unwrap()])
        .expect("execute");
    let (p_jax, il_jax) = (&outs[0], outs[1][0]);

    let mut p_rust = Vec::new();
    let pv = gsparse::sparsify::greedy_probs(&g, 0.1, 2, &mut p_rust);
    assert!(
        (pv.inv_lambda - il_jax).abs() / il_jax.max(1e-9) < 1e-4,
        "inv_lambda: rust {} vs jax {il_jax}",
        pv.inv_lambda
    );
    for i in 0..d {
        assert!(
            (p_rust[i] - p_jax[i]).abs() < 1e-4,
            "p[{i}]: rust {} vs jax {}",
            p_rust[i],
            p_jax[i]
        );
    }
}

#[test]
fn logistic_grad_artifact_matches_rust_model() {
    let Some(mut rt) = runtime() else { return };
    let (b, d) = (8usize, 2048usize);
    let reg = 1.0f32 / (10.0 * 1024.0);
    let ds = gsparse::data::gen_logistic(b, d, 0.6, 0.25, 7);
    let model = gsparse::model::LogisticModel::new(reg);
    let mut rng = gsparse::rngkit::Xoshiro256pp::seed_from_u64(8);
    let w: Vec<f32> = (0..d).map(|_| (rng.next_gaussian() * 0.05) as f32).collect();

    // Rust analytic gradient over the whole mini-dataset.
    let idx: Vec<usize> = (0..b).collect();
    let mut g_rust = vec![0.0f32; d];
    model.grad_minibatch(&ds, &w, &idx, &mut g_rust);

    // JAX artifact.
    let x_flat: Vec<f32> = (0..b).flat_map(|r| ds.x.row(r).to_vec()).collect();
    let exe = rt.get("logistic_grad").expect("artifact");
    let outs = exe
        .run_f32(&[
            lit::f32_tensor(&x_flat, &[b as i64, d as i64]).unwrap(),
            lit::f32_tensor(&ds.y, &[b as i64]).unwrap(),
            lit::f32_tensor(&w, &[d as i64]).unwrap(),
        ])
        .expect("execute");
    let g_jax = &outs[0];
    let loss_jax = outs[1][0] as f64;

    let loss_rust = model.loss(&ds, &w);
    assert!(
        (loss_rust - loss_jax).abs() < 1e-4 * (1.0 + loss_rust.abs()),
        "loss: rust {loss_rust} vs jax {loss_jax}"
    );
    for i in 0..d {
        assert!(
            (g_rust[i] - g_jax[i]).abs() < 1e-4,
            "grad[{i}]: rust {} vs jax {}",
            g_rust[i],
            g_jax[i]
        );
    }
}

#[test]
fn fused_grad_probs_artifact_consistent() {
    let Some(mut rt) = runtime() else { return };
    let (b, d) = (8usize, 2048usize);
    let ds = gsparse::data::gen_logistic(b, d, 0.9, 0.0625, 9);
    let mut rng = gsparse::rngkit::Xoshiro256pp::seed_from_u64(10);
    let w: Vec<f32> = (0..d).map(|_| (rng.next_gaussian() * 0.02) as f32).collect();
    let x_flat: Vec<f32> = (0..b).flat_map(|r| ds.x.row(r).to_vec()).collect();
    let exe = rt.get("logistic_grad_probs").expect("artifact");
    let outs = exe
        .run_f32(&[
            lit::f32_tensor(&x_flat, &[b as i64, d as i64]).unwrap(),
            lit::f32_tensor(&ds.y, &[b as i64]).unwrap(),
            lit::f32_tensor(&w, &[d as i64]).unwrap(),
        ])
        .expect("execute");
    let (grad, p, inv_lambda) = (&outs[0], &outs[2], outs[3][0]);
    // The fused probabilities must equal Rust greedy probs of the gradient.
    let mut p_rust = Vec::new();
    let pv = gsparse::sparsify::greedy_probs(grad, 0.1, 2, &mut p_rust);
    assert!((pv.inv_lambda - inv_lambda).abs() / inv_lambda.max(1e-9) < 1e-3);
    for i in 0..d {
        assert!(
            (p_rust[i] - p[i]).abs() < 1e-3,
            "p[{i}]: {} vs {}",
            p_rust[i],
            p[i]
        );
    }
}

#[test]
fn cnn_step_trains_through_cluster() {
    let Some(mut rt) = runtime() else { return };
    // Smallest CNN variant; 2 workers; per-layer GSpar; few Adam steps.
    let step = HloTrainStep::from_manifest(&mut rt, "cnn24_step").expect("manifest spec");
    assert!(step.total_params() > 50_000, "CNN should be non-trivial");
    let mut params = step.init_params(&mut rt, 0).expect("init");

    let ds = gsparse::data::CifarLike::generate(64, 3);
    let bsz = step.x_dims[0];
    let layer_dims = step.layer_dims();
    let session = gsparse::api::Session::builder()
        .method(gsparse::api::MethodSpec::GSpar { rho: 0.05, iters: 2 })
        .workers(2)
        .seed(4)
        .build();
    let mut cluster = session.cluster(&layer_dims);
    let mut adams: Vec<gsparse::opt::Adam> = layer_dims
        .iter()
        .map(|&dim| gsparse::opt::Adam::new(dim, 0.02))
        .collect();

    let mut rng = gsparse::rngkit::Xoshiro256pp::seed_from_u64(5);
    let mut x = vec![0.0f32; bsz * gsparse::data::CifarLike::PIXELS];
    let mut y = vec![0i32; bsz];
    let mut first_loss = None;
    let mut last_loss = 0.0f32;
    for _ in 0..6 {
        // Leader computes both workers' gradients via PJRT (client is !Send).
        let mut worker_grads = Vec::new();
        let mut losses = Vec::new();
        for _ in 0..2 {
            let idx: Vec<usize> = (0..bsz)
                .map(|_| rng.next_below(ds.n as u64) as usize)
                .collect();
            ds.batch_into(&idx, &mut x, &mut y);
            let (loss, grads) = step.grads(&mut rt, &params, &x, &y).expect("step");
            losses.push(loss);
            worker_grads.push(grads);
        }
        let loss = losses.iter().sum::<f32>() / 2.0;
        if first_loss.is_none() {
            first_loss = Some(loss);
        }
        last_loss = loss;
        let updates = cluster.round(&worker_grads);
        for ((p, upd), adam) in params.iter_mut().zip(&updates).zip(adams.iter_mut()) {
            adam.step(p, &upd.grad);
        }
    }
    let first = first_loss.unwrap();
    assert!(
        last_loss < first,
        "CNN loss should decrease: {first} -> {last_loss}"
    );
    assert!(cluster.ledger.wire_bytes > 0);
    assert!(cluster.spa_meter.value() < 0.2, "per-layer sparsification active");
}

#[test]
fn transformer_step_loss_near_uniform_at_init() {
    let Some(mut rt) = runtime() else { return };
    let step = HloTrainStep::from_manifest(&mut rt, "transformer_step").expect("spec");
    let params = step.init_params(&mut rt, 1).expect("init");
    let (bsz, seq) = (step.x_dims[0], step.x_dims[1]);
    let corpus = gsparse::data::ByteCorpus::generate(10_000, 64, 2);
    let mut rng = gsparse::rngkit::Xoshiro256pp::seed_from_u64(3);
    let mut toks = Vec::new();
    let mut tgts = Vec::new();
    for _ in 0..bsz {
        let (t, y) = corpus.sample_window(seq, &mut rng);
        toks.extend(t);
        tgts.extend(y);
    }
    let x_f32: Vec<f32> = Vec::new(); // transformer takes i32 tokens, not f32 x
    let _ = x_f32;
    // Execute directly (tokens are i32, so bypass HloTrainStep::grads's f32 x).
    let mut inputs = Vec::new();
    for (p, spec) in params.iter().zip(&step.params) {
        inputs.push(
            lit::f32_tensor(p, &spec.dims.iter().map(|&d| d as i64).collect::<Vec<_>>()).unwrap(),
        );
    }
    inputs.push(lit::i32_tensor(&toks, &[bsz as i64, seq as i64]).unwrap());
    inputs.push(lit::i32_tensor(&tgts, &[bsz as i64, seq as i64]).unwrap());
    let exe = rt.get("transformer_step").expect("artifact");
    let outs = exe.run_f32(&inputs).expect("execute");
    let loss = outs[0][0];
    let uniform = (64f32).ln();
    assert!(
        (loss - uniform).abs() < 0.6,
        "init loss {loss} should be near ln(64)={uniform}"
    );
    assert_eq!(outs.len(), params.len() + 1);
}
