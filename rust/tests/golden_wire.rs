//! Golden wire-format snapshots: hand-constructed messages with their
//! encoded bytes committed as hex, one fixture per (message, codec) pair.
//!
//! These pin the *byte-level* format of both codecs — header layout,
//! encoding choice, Rice parameter selection, bit order, padding — so any
//! drift breaks this test before it breaks cross-version TCP
//! compatibility. The messages are hand-built (not sampled) so the
//! fixtures cannot rot when solver or RNG internals change; drift here
//! means the *codec* changed and the wire version must be bumped.

use gsparse::coding::{self, Encoding, WireCodec};
use gsparse::sparsify::SparseGrad;

struct Fixture {
    name: &'static str,
    msg: SparseGrad,
    raw_hex: &'static str,
    raw_enc: Encoding,
    entropy_hex: &'static str,
    entropy_enc: Encoding,
}

fn msg(d: usize, exact: &[(u32, f32)], shared: &[(u32, bool)], mag: f32) -> SparseGrad {
    let mut sg = SparseGrad::empty(d);
    sg.exact.extend_from_slice(exact);
    sg.shared.extend_from_slice(shared);
    sg.shared_mag = mag;
    sg
}

fn fixtures() -> Vec<Fixture> {
    vec![
        Fixture {
            name: "empty_d100",
            msg: msg(100, &[], &[], 0.0),
            raw_hex: "475350520100000064000000000000000000000000000000",
            raw_enc: Encoding::Indexed,
            entropy_hex: "475350520100000064000000000000000000000000000000",
            entropy_enc: Encoding::Indexed,
        },
        Fixture {
            name: "mixed_d1000",
            msg: msg(
                1000,
                &[(3, 1.5), (701, -2.25)],
                &[(0, false), (17, true), (250, false), (999, true)],
                0.5,
            ),
            raw_hex: "4753505201000000e803000002000000040000000000003f0300000000\
                      00c03fbd020000000010c00000000011000000fa000000e70300000a",
            raw_enc: Encoding::Indexed,
            entropy_hex: "4753505201020807e803000002000000040000000000003f0000c03f\
                          000010c00a06960b0012fa6303",
            entropy_enc: Encoding::IndexedRice,
        },
        Fixture {
            name: "dense_d16",
            msg: msg(
                16,
                &[(1, 1.0)],
                &[
                    (0, true),
                    (2, false),
                    (5, false),
                    (6, true),
                    (9, false),
                    (11, true),
                    (13, false),
                    (15, true),
                ],
                0.25,
            ),
            raw_hex: "47535052010100001000000001000000080000000000803e1e2484840000803f",
            raw_enc: Encoding::DenseSymbols,
            entropy_hex: "47535052010100001000000001000000080000000000803e1e2484840000803f",
            entropy_enc: Encoding::DenseSymbols,
        },
        Fixture {
            name: "rice_d4096",
            msg: msg(
                4096,
                &[(100, 3.0), (2000, -4.5)],
                &[
                    (64, false),
                    (320, true),
                    (576, false),
                    (832, false),
                    (1088, true),
                    (1344, false),
                    (1600, true),
                    (1856, false),
                    (2112, false),
                    (2368, true),
                    (2624, false),
                    (2880, false),
                    (3136, true),
                    (3392, false),
                    (3648, true),
                    (3904, false),
                ],
                0.125,
            ),
            raw_hex: "47535052010000000010000002000000100000000000003e6400000000004040\
                      d0070000000090c040000000400100004002000040030000400400004005000040\
                      060000400700004008000040090000400a0000400b0000400c0000400d0000400e\
                      0000400f00005252",
            raw_enc: Encoding::Indexed,
            entropy_hex: "47535052010209070010000002000000100000000000003e0000404000\
                          0090c05252c8dc5ac0fefdfbf7efdfbf7ffffefdfbf7efdfbf3f",
            entropy_enc: Encoding::IndexedRice,
        },
    ]
}

fn from_hex(s: &str) -> Vec<u8> {
    let clean: String = s.chars().filter(|c| !c.is_whitespace()).collect();
    assert_eq!(clean.len() % 2, 0, "odd hex fixture length");
    (0..clean.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(&clean[i..i + 2], 16).expect("hex digit"))
        .collect()
}

fn to_hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

#[test]
fn golden_bytes_have_not_drifted() {
    for f in fixtures() {
        for (codec, hex, want_enc) in [
            (WireCodec::Raw, f.raw_hex, f.raw_enc),
            (WireCodec::Entropy, f.entropy_hex, f.entropy_enc),
        ] {
            let mut buf = Vec::new();
            let enc = coding::encode_with(&f.msg, codec, &mut buf);
            assert_eq!(enc, want_enc, "{}/{codec}: encoding choice drifted", f.name);
            let want = from_hex(hex);
            assert_eq!(
                buf,
                want,
                "{}/{codec}: byte drift\n  have {}\n  want {}",
                f.name,
                to_hex(&buf),
                to_hex(&want),
            );
        }
    }
}

#[test]
fn golden_bytes_decode_to_the_fixture_messages() {
    // The committed bytes — not freshly encoded ones — must decode to the
    // exact message, so an old peer's frames stay readable as long as this
    // test passes.
    for f in fixtures() {
        for (codec, hex) in [(WireCodec::Raw, f.raw_hex), (WireCodec::Entropy, f.entropy_hex)] {
            let bytes = from_hex(hex);
            let back = coding::decode(&bytes)
                .unwrap_or_else(|e| panic!("{}/{codec}: fixture undecodable: {e}", f.name));
            assert_eq!(back, f.msg, "{}/{codec}: decoded message drifted", f.name);
        }
    }
}

#[test]
fn golden_entropy_fixture_is_smaller_where_rice_engages() {
    for f in fixtures() {
        let raw = from_hex(f.raw_hex).len();
        let ent = from_hex(f.entropy_hex).len();
        assert!(ent <= raw, "{}: entropy fixture larger than raw", f.name);
        if f.entropy_enc == Encoding::IndexedRice {
            assert!(ent < raw, "{}: rice engaged but saved nothing", f.name);
        }
    }
}

// ---------------------------------------------------------------------------
// WireBatch fixtures: the batched multi-layer frame, one hex snapshot per
// (layer list, codec). Batch sub-payloads are byte-identical to the
// single-message payloads above (only the headers and Rice-parameter
// placement differ), so the expected bytes are derived from the committed
// single-message fixtures — any drift here is codec drift, not fixture rot.
// ---------------------------------------------------------------------------

struct BatchFixture {
    name: &'static str,
    layers: Vec<SparseGrad>,
    raw_hex: &'static str,
    entropy_hex: &'static str,
}

fn mixed_d1000() -> SparseGrad {
    msg(
        1000,
        &[(3, 1.5), (701, -2.25)],
        &[(0, false), (17, true), (250, false), (999, true)],
        0.5,
    )
}

/// `d % 4 != 0` layer that encodes as DenseSymbols (high density).
fn dense_d5() -> SparseGrad {
    msg(
        5,
        &[(0, 1.0)],
        &[(1, false), (2, true), (3, false), (4, true)],
        0.25,
    )
}

fn batch_fixtures() -> Vec<BatchFixture> {
    vec![
        // Single empty layer: Indexed sub-message, zero Rice params.
        BatchFixture {
            name: "batch_empty_d100",
            layers: vec![msg(100, &[], &[], 0.0)],
            raw_hex: "475350420200000001000000\
                      0064000000000000000000000000000000",
            entropy_hex: "475350420201000001000000\
                          0064000000000000000000000000000000",
        },
        // Single mixed layer: the sub-payloads are exactly the
        // single-message `mixed_d1000` payloads; under entropy the shared
        // Rice parameters equal the per-message ones (same gap streams).
        BatchFixture {
            name: "batch_mixed_d1000",
            layers: vec![mixed_d1000()],
            raw_hex: "475350420200000001000000\
                      00e803000002000000040000000000003f\
                      030000000000c03fbd020000000010c000000000\
                      11000000fa000000e70300000a",
            entropy_hex: "475350420201080701000000\
                          02e803000002000000040000000000003f\
                          0000c03f000010c00a06960b0012fa6303",
        },
        // DenseSymbols layer with d % 4 != 0 plus an empty layer: no
        // sub-message uses Rice, so header bytes 6–7 stay zero under both
        // codecs and the encodings coincide byte-for-byte (bar the codec
        // byte).
        BatchFixture {
            name: "batch_dense_d5_plus_empty_d3",
            layers: vec![dense_d5(), msg(3, &[], &[], 0.0)],
            raw_hex: "475350420200000002000000\
                      010500000001000000040000000000803e\
                      67020000803f\
                      0003000000000000000000000000000000",
            entropy_hex: "475350420201000002000000\
                          010500000001000000040000000000803e\
                          67020000803f\
                          0003000000000000000000000000000000",
        },
        // Two identical layers: the pooled gap distribution doubles every
        // count, so the shared parameters match the per-message optimum
        // and both sub-messages reuse the single-message Rice payload.
        BatchFixture {
            name: "batch_two_mixed_d1000",
            layers: vec![mixed_d1000(), mixed_d1000()],
            raw_hex: "475350420200000002000000\
                      00e803000002000000040000000000003f\
                      030000000000c03fbd020000000010c000000000\
                      11000000fa000000e70300000a\
                      00e803000002000000040000000000003f\
                      030000000000c03fbd020000000010c000000000\
                      11000000fa000000e70300000a",
            entropy_hex: "475350420201080702000000\
                          02e803000002000000040000000000003f\
                          0000c03f000010c00a06960b0012fa6303\
                          02e803000002000000040000000000003f\
                          0000c03f000010c00a06960b0012fa6303",
        },
        // Version-2 parameter-delta byte. The pooled QB gap multiset is
        // {0 × 8} ∪ {127 × 4} → shared kb = 5. The consecutive-index layer
        // (gap scale 0) strictly wins by running at k = 0 behind the delta
        // byte 0x0b (dkb = −5); the strided layer's per-layer optimum
        // (k = 6) only ties the pooled form, so it stays flag-free — one
        // batch exercising both outcomes. Layer signs alternate so the QB
        // bitmaps are non-trivial; the trailing empty layer keeps the batch
        // strictly smaller than per-message framing.
        BatchFixture {
            name: "batch_param_delta_mixed_scales",
            layers: vec![
                msg(
                    64,
                    &[],
                    &[
                        (0, false),
                        (1, true),
                        (2, false),
                        (3, true),
                        (4, false),
                        (5, true),
                        (6, false),
                        (7, true),
                    ],
                    1.0,
                ),
                msg(
                    512,
                    &[],
                    &[(127, true), (255, false), (383, true), (511, false)],
                    0.5,
                ),
                msg(12, &[], &[], 0.0),
            ],
            raw_hex: "475350420200000003000000\
                      01400000000000000008000000 0000803f\
                      99990000000000000000000000000000\
                      00000200000000000004000000 0000003f\
                      7f000000ff0000007f010000ff01000005\
                      000c000000000000000000000000000000",
            entropy_hex: "475350420201000503000000\
                          82400000000000000008000000 0000803f\
                          0baa00\
                          02000200000000000004000000 0000003f\
                          05f7efdfbf0f\
                          000c000000000000000000000000000000",
        },
    ]
}

#[test]
fn golden_batch_bytes_have_not_drifted() {
    for f in batch_fixtures() {
        let refs: Vec<&SparseGrad> = f.layers.iter().collect();
        for (codec, hex) in [
            (WireCodec::Raw, f.raw_hex),
            (WireCodec::Entropy, f.entropy_hex),
        ] {
            let mut buf = Vec::new();
            coding::encode_batch(&refs, codec, &mut buf);
            assert_eq!(
                buf.len(),
                coding::encoded_batch_len(&refs, codec),
                "{}/{codec}: length formula drifted",
                f.name
            );
            let want = from_hex(hex);
            assert_eq!(
                buf,
                want,
                "{}/{codec}: byte drift\n  have {}\n  want {}",
                f.name,
                to_hex(&buf),
                to_hex(&want),
            );
        }
    }
}

#[test]
fn golden_batch_bytes_decode_to_the_fixture_layers() {
    // The committed bytes — not freshly encoded ones — must decode to the
    // exact layer lists, so an old peer's batch frames stay readable.
    for f in batch_fixtures() {
        for (codec, hex) in [
            (WireCodec::Raw, f.raw_hex),
            (WireCodec::Entropy, f.entropy_hex),
        ] {
            let bytes = from_hex(hex);
            let mut out = Vec::new();
            let mut sub_lens = Vec::new();
            coding::decode_batch_into(&bytes, &mut out, &mut sub_lens)
                .unwrap_or_else(|e| panic!("{}/{codec}: fixture undecodable: {e}", f.name));
            assert_eq!(out, f.layers, "{}/{codec}: decoded layers drifted", f.name);
            assert_eq!(
                sub_lens.iter().sum::<usize>() + coding::BATCH_HEADER_LEN,
                bytes.len(),
                "{}/{codec}: sub lengths must tile the batch",
                f.name
            );
        }
    }
}

#[test]
fn golden_v1_spellings_still_behave() {
    // A delta-free v2 batch differs from its v1 spelling only in the
    // version byte, so patching it back must keep decoding byte-for-byte —
    // that is the wire-compatibility promise to older peers. A batch that
    // *does* carry a delta flag has no v1 spelling: the patched bytes must
    // be rejected, not misread.
    for f in batch_fixtures() {
        for (codec, hex) in [
            (WireCodec::Raw, f.raw_hex),
            (WireCodec::Entropy, f.entropy_hex),
        ] {
            let bytes = from_hex(hex);
            assert_eq!(bytes[4], coding::BATCH_VERSION, "{}: fixture version", f.name);
            let mut out = Vec::new();
            let mut sub_lens = Vec::new();
            coding::decode_batch_into(&bytes, &mut out, &mut sub_lens).unwrap();
            let mut any_delta = false;
            let mut off = coding::BATCH_HEADER_LEN;
            for &len in &sub_lens {
                any_delta |= bytes[off] & coding::PARAM_DELTA_FLAG != 0;
                off += len;
            }
            let mut v1 = bytes.clone();
            v1[4] = 1;
            let res = coding::decode_batch_into(&v1, &mut out, &mut sub_lens);
            if any_delta {
                assert!(
                    matches!(res, Err(coding::WireError::BadParamDelta(_))),
                    "{}/{codec}: delta batch must have no v1 spelling, got {res:?}",
                    f.name
                );
            } else {
                res.unwrap_or_else(|e| panic!("{}/{codec}: v1 spelling undecodable: {e}", f.name));
                assert_eq!(out, f.layers, "{}/{codec}: v1 spelling drifted", f.name);
            }
        }
    }
    // The delta fixture actually exercises the delta path: its first
    // entropy sub-message must carry the flag and the committed 0x0b byte.
    let f = &batch_fixtures()[4];
    assert_eq!(f.name, "batch_param_delta_mixed_scales");
    let bytes = from_hex(f.entropy_hex);
    let enc_at = coding::BATCH_HEADER_LEN;
    assert_ne!(bytes[enc_at] & coding::PARAM_DELTA_FLAG, 0, "delta flag missing");
    assert_eq!(bytes[enc_at + coding::SUB_HEADER_LEN], 0x0b, "delta byte drifted");
}

#[test]
fn golden_batch_headers_beat_per_layer_headers() {
    // The point of the format: for every fixture the batch is at most as
    // large as the framed sum of its single-message encodings, and strictly
    // smaller whenever there is more than one layer.
    for f in batch_fixtures() {
        let refs: Vec<&SparseGrad> = f.layers.iter().collect();
        for codec in [WireCodec::Raw, WireCodec::Entropy] {
            let batch = coding::encoded_batch_len(&refs, codec);
            let singles: usize = f
                .layers
                .iter()
                .map(|sg| coding::encoded_len_with(sg, codec))
                .sum();
            if f.layers.len() > 1 {
                assert!(
                    batch < singles,
                    "{}/{codec}: batch {batch} !< singles {singles}",
                    f.name
                );
            } else {
                assert!(
                    batch <= singles + coding::BATCH_HEADER_LEN,
                    "{}/{codec}: batch overhead out of bounds",
                    f.name
                );
            }
        }
    }
}
