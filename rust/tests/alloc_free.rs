//! Steady-state allocation accounting for the compression hot path.
//!
//! A counting global allocator wraps the system allocator; after a warmup
//! phase that grows every reusable buffer to its plateau, the fused
//! `CompressEngine::compress_into` path and every `Compressor::compress_into`
//! implementation must perform **zero** heap allocations per call (the
//! acceptance criterion of the allocation-free engine work). The checks run
//! inside a single `#[test]` so no concurrent test thread can pollute the
//! counter.

use gsparse::benchkit::{allocation_count, CountingAllocator};
use gsparse::coding::{self, WireCodec, WireError};
use gsparse::comm::{Aggregator, NetworkModel, ReduceAlgo};
use gsparse::config::Method;
use gsparse::rngkit::RandArray;
use gsparse::sparsify::{Compressed, CompressEngine, SparseGrad};

#[global_allocator]
static GLOBAL: CountingAllocator = CountingAllocator;

fn gradient(d: usize, seed: u64) -> Vec<f32> {
    gsparse::benchkit::skewed_gradient(d, seed, 0.1)
}

/// Run `f` `calls` times and return the number of allocations observed.
fn count_allocs<F: FnMut()>(calls: usize, mut f: F) -> u64 {
    let before = allocation_count();
    for _ in 0..calls {
        f();
    }
    allocation_count() - before
}

#[test]
fn steady_state_compression_is_allocation_free() {
    let d = 8192; // below the parallel threshold: the sequential fused path
    let g = gradient(d, 1);
    let calls = 64;

    // --- Fused engine, greedy mode -------------------------------------
    let mut engine = CompressEngine::greedy(0.05, 2);
    engine.reserve(d);
    let mut rand = RandArray::from_seed(2, 1 << 18);
    let mut out = SparseGrad::empty(d);
    // Worst-case capacity: every coordinate could survive.
    out.exact.reserve(d);
    out.shared.reserve(d);
    let mut wire = Vec::with_capacity(gsparse::coding::HEADER_LEN + 9 * d);
    for _ in 0..8 {
        engine.compress_into(&g, &mut rand, &mut out, &mut wire); // warmup
    }
    let n = count_allocs(calls, || {
        engine.compress_into(&g, &mut rand, &mut out, &mut wire);
    });
    assert_eq!(n, 0, "greedy engine compress_into allocated {n} times in {calls} calls");

    // --- Fused engine, closed-form (selection solver) ------------------
    let mut engine = CompressEngine::closed_form(0.5);
    engine.reserve(d);
    for _ in 0..8 {
        engine.compress_into(&g, &mut rand, &mut out, &mut wire);
    }
    let n = count_allocs(calls, || {
        engine.compress_into(&g, &mut rand, &mut out, &mut wire);
    });
    assert_eq!(n, 0, "closed-form engine compress_into allocated {n} times in {calls} calls");

    // --- Every Compressor::compress_into implementation ----------------
    for &method in Method::all() {
        let mut c = gsparse::api::MethodSpec::from_parts(method, 0.1, 0.5, 4).build();
        let mut msg = Compressed::Sparse(SparseGrad::empty(d));
        for _ in 0..8 {
            c.compress_into(&g, &mut rand, &mut msg); // warmup grows buffers
        }
        let n = count_allocs(calls, || {
            c.compress_into(&g, &mut rand, &mut msg);
        });
        assert_eq!(
            n, 0,
            "{method}: compress_into allocated {n} times in {calls} calls"
        );
    }

    // --- Error-feedback adapter: residual arena + scratch reuse --------
    // (OneBit above already runs through WithFeedback<SignCompressor>;
    // this pins the adapter around a sparse compressor explicitly.)
    {
        let mut c = gsparse::feedback::WithFeedback::new(
            gsparse::sparsify::TopKCompressor::new(0.05),
        );
        let mut msg = Compressed::Sparse(SparseGrad::empty(d));
        for _ in 0..8 {
            gsparse::sparsify::Compressor::compress_into(&mut c, &g, &mut rand, &mut msg);
        }
        let n = count_allocs(calls, || {
            gsparse::sparsify::Compressor::compress_into(&mut c, &g, &mut rand, &mut msg);
        });
        assert_eq!(
            n, 0,
            "WithFeedback<TopK>: compress_into allocated {n} times in {calls} calls"
        );
    }

    // --- Aggregator reduce (encode → decode_into → average) ------------
    let mut engine = CompressEngine::greedy(0.05, 2);
    let mut grads: Vec<SparseGrad> = Vec::new();
    for wseed in 0..4 {
        let gw = gradient(d, 100 + wseed);
        let mut sg = SparseGrad::empty(d);
        engine.compress_sparse_into(&gw, &mut rand, &mut sg);
        grads.push(sg);
    }
    let mut agg = Aggregator::new(NetworkModel::datacenter_10g(), ReduceAlgo::Sparse);
    let mut v = vec![0.0f32; d];
    for _ in 0..4 {
        agg.reduce(&grads, &mut v); // warmup
    }
    let n = count_allocs(16, || {
        agg.reduce(&grads, &mut v);
    });
    assert_eq!(n, 0, "Aggregator::reduce allocated {n} times in 16 calls");

    // --- Both wire codecs: steady-state encode + decode ----------------
    // (Still the same #[test]: the counter is global.) After warmup, the
    // encode → decode_into cycle must be allocation-free for Raw and
    // Entropy alike — the Rice bit writer works in the caller's buffer.
    {
        let d = 8192;
        let g = gradient(d, 21);
        let mut engine = CompressEngine::greedy(0.02, 2);
        engine.reserve(d);
        let mut rand = RandArray::from_seed(22, 1 << 18);
        let mut sg = SparseGrad::empty(d);
        sg.exact.reserve(d);
        sg.shared.reserve(d);
        engine.compress_sparse_into(&g, &mut rand, &mut sg);
        let mut wire = Vec::with_capacity(coding::HEADER_LEN + 9 * d);
        let mut slot = SparseGrad::empty(0);
        slot.exact.reserve(d);
        slot.shared.reserve(d);
        for &codec in WireCodec::all() {
            for _ in 0..4 {
                coding::encode_with(&sg, codec, &mut wire); // warmup
                coding::decode_into(&wire, &mut slot).unwrap();
            }
            let n = count_allocs(32, || {
                coding::encode_with(&sg, codec, &mut wire);
                coding::decode_into(&wire, &mut slot).unwrap();
            });
            assert_eq!(n, 0, "{codec}: encode+decode allocated {n} times in 32 calls");
            assert_eq!(slot, sg, "{codec}: roundtrip drifted");
        }

        // Adversarial decodes must reject *without allocating*, exactly
        // like the CountsExceedDim gate: build the corrupted buffers
        // first, then count only the decode calls.
        let enc = coding::encode_with(&sg, WireCodec::Entropy, &mut wire);
        assert_eq!(enc, coding::Encoding::IndexedRice, "workload must pick rice");
        let mut bad_param = wire.clone();
        bad_param[7] = 33;
        let truncated: Vec<u8> = wire[..wire.len() - 1].to_vec();
        let mut bad_counts = wire.clone();
        bad_counts[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
        // A hand-built rice message whose final byte provably has five
        // padding bits, with the top one flipped (see the codec unit
        // tests for the layout).
        let mut bad_pad: Vec<u8> = Vec::new();
        bad_pad.extend_from_slice(b"GSPR");
        bad_pad.extend_from_slice(&[1, 2, 0, 0]);
        bad_pad.extend_from_slice(&8u32.to_le_bytes());
        bad_pad.extend_from_slice(&0u32.to_le_bytes());
        bad_pad.extend_from_slice(&1u32.to_le_bytes());
        bad_pad.extend_from_slice(&1.0f32.to_le_bytes());
        bad_pad.push(0); // sign bitmap
        bad_pad.push(0b1000_0011); // gap 2, nonzero padding bit
        let n = count_allocs(16, || {
            assert_eq!(
                coding::decode_into(&bad_param, &mut slot),
                Err(WireError::BadRiceParam(33))
            );
            assert!(coding::decode_into(&truncated, &mut slot).is_err());
            assert_eq!(
                coding::decode_into(&bad_pad, &mut slot),
                Err(WireError::BadRiceStream("nonzero padding"))
            );
            assert!(matches!(
                coding::decode_into(&bad_counts, &mut slot),
                Err(WireError::CountsExceedDim { .. })
            ));
        });
        assert_eq!(n, 0, "adversarial decodes allocated {n} times in 16 calls");
    }

    // --- Trace recording: allocation-free in steady state ---------------
    // (Same #[test], same reason.) The recorder preallocates each thread's
    // ring at install time; recording a span afterwards is a clock read
    // plus a write into that ring — zero allocations per span, the
    // tentpole "never blocks, never allocates in the hot loop" criterion.
    // Ring overflow overwrites in place, so a full ring stays free too.
    {
        use gsparse::trace::{self, Stage, TraceConfig};
        let rec = trace::Recorder::new(&TraceConfig::On {
            capacity: 256,
            format: trace::TraceFormat::Chrome,
        })
        .unwrap();
        let guard = trace::install(&rec, 0); // ring allocated here (warmup)
        trace::set_round(1);
        for _ in 0..8 {
            let mut s = trace::span(Stage::Encode);
            s.bytes(64);
        }
        let n = count_allocs(1024, || {
            let mut s = trace::span(Stage::Solve);
            s.bytes(4096);
            drop(s);
            trace::counter(Stage::FrameTx, 128);
        });
        assert_eq!(n, 0, "span recording allocated {n} times in 1024 calls");
        // Disabled-path cost: with no recorder installed on the thread the
        // instrumentation must not allocate either (it is one atomic load).
        drop(guard);
        let n = count_allocs(1024, || {
            let mut s = trace::span(Stage::Solve);
            s.bytes(4096);
        });
        assert_eq!(n, 0, "inert spans allocated {n} times in 1024 calls");
    }

    // --- Telemetry registry: updates are allocation-free -----------------
    // (Same #[test], same reason.) Registration takes the registry lock
    // and allocates; the returned handles are Arcs over atomics, so every
    // subsequent inc/set/observe must be a pure RMW — the `/metrics` hot
    // path promise.
    {
        use gsparse::telemetry::Registry;
        let reg = Registry::new();
        let c = reg.counter("af_rounds_total", "alloc test", &[("worker", "0")]);
        let gauge = reg.gauge("af_straggler_ratio", "alloc test", &[]);
        let h = reg.histogram(
            "af_round_latency_seconds",
            "alloc test",
            &[("worker", "0")],
            &[1e-3, 1e-2, 1e-1, 1.0],
        );
        for _ in 0..8 {
            c.inc();
            gauge.set(1.25);
            h.observe(0.02); // warmup (nothing to grow, but symmetric)
        }
        let n = count_allocs(1024, || {
            c.inc_by(3);
            gauge.set(2.5);
            h.observe(0.004);
            h.observe(7.0); // +Inf bucket, same promise
        });
        assert_eq!(n, 0, "registry updates allocated {n} times in 1024 calls");
    }

    // --- Sharded path: shard buffers reused ----------------------------
    // (Same #[test] on purpose: a concurrent test thread would pollute the
    // global counter.) The parallel path runs on the persistent ShardPool —
    // threads are spawned once, not per call — so the steady-state cost is
    // a handful of job boxes and queue nodes per call; shard buffers must
    // be reused, keeping the per-call count bounded and far below one
    // allocation per coordinate.
    let d = 1 << 17;
    let g = gradient(d, 7);
    let mut engine = CompressEngine::greedy(0.05, 2).with_sharding(1 << 14, 1, 4);
    let mut rand = RandArray::from_seed(8, 1 << 20);
    let mut out = SparseGrad::empty(d);
    let mut wire = Vec::new();
    for _ in 0..4 {
        engine.compress_into(&g, &mut rand, &mut out, &mut wire);
    }
    let calls = 8;
    let n = count_allocs(calls, || {
        engine.compress_into(&g, &mut rand, &mut out, &mut wire);
    });
    let per_call = n as f64 / calls as f64;
    // Budget: ~4 thread spawns/call at ≲ 16 allocations each, nothing per
    // shard or per coordinate (d = 131072 here).
    assert!(
        per_call < 256.0,
        "sharded path: {per_call} allocations/call — shard buffers not reused?"
    );
}
