//! Integration coverage for the Algorithm-4 shared-memory engine
//! (`coordinator::async_engine`): single-thread determinism, tracing
//! transparency, and the report's ledger columns.

use gsparse::config::{AsyncSvmConfig, Method, UpdateScheme};
use gsparse::coordinator::AsyncSvmEngine;
use gsparse::data::gen_svm;
use std::sync::{Mutex, OnceLock};

fn cfg(method: Method, scheme: UpdateScheme, threads: usize, seed: u64) -> AsyncSvmConfig {
    AsyncSvmConfig {
        n: 512,
        d: 64,
        c1: 0.01,
        c2: 0.9,
        reg: 0.1,
        rho: 0.1,
        threads,
        lr: 0.05,
        method,
        seed,
        total_steps: 4_000,
        scheme,
    }
}

/// One test in this binary mutates `GSPARSE_TRACE`; every test that runs an
/// engine (which reads that variable) takes this lock so the mutation is
/// never concurrent with a read.
fn env_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

#[test]
fn single_thread_run_is_deterministic_given_seed() {
    // One worker thread means one claim order, one RNG stream, and a
    // serial apply order — the whole run must replay bitwise. (Multi-thread
    // schedules are genuinely racy by design; determinism is only claimed
    // at threads = 1.)
    let _env = env_lock().lock().unwrap();
    let ds = gen_svm(512, 64, 0.01, 0.9, 33);
    let run = || AsyncSvmEngine::new(cfg(Method::GSpar, UpdateScheme::Lock, 1, 33)).run(&ds);
    let a = run();
    let b = run();
    assert_eq!(a.final_loss, b.final_loss, "final weights must replay");
    assert_eq!(a.updates, b.updates, "update count must replay");
    assert_eq!(a.conflicts, 0, "Lock scheme never CAS-retries");
    assert_eq!(b.conflicts, 0);
}

#[test]
fn single_thread_schemes_agree_bitwise() {
    // With one thread there is no concurrency, so Lock / Atomic / Wild are
    // the same sequential algorithm — identical final weights.
    let _env = env_lock().lock().unwrap();
    let ds = gen_svm(512, 64, 0.01, 0.9, 34);
    let run = |scheme| AsyncSvmEngine::new(cfg(Method::GSpar, scheme, 1, 34)).run(&ds);
    let lock = run(UpdateScheme::Lock);
    let atomic = run(UpdateScheme::Atomic);
    let wild = run(UpdateScheme::Wild);
    assert_eq!(lock.final_loss, atomic.final_loss);
    assert_eq!(lock.final_loss, wild.final_loss);
    assert_eq!(lock.updates, atomic.updates);
    assert_eq!(lock.updates, wild.updates);
}

#[test]
fn report_ledger_columns_stay_consistent() {
    // Algorithm 4 is shared-memory: nothing crosses a wire, so every
    // ledger column must stay zero — and the cross-column consistency
    // predicate (wire split == wire total, measured ⊇ wire, messages vs
    // bytes) must hold on that all-zero ledger, exactly as `verify()`
    // asserts on the four transport-backed coordinators.
    let _env = env_lock().lock().unwrap();
    let ds = gen_svm(512, 64, 0.01, 0.9, 35);
    let report = AsyncSvmEngine::new(cfg(Method::GSpar, UpdateScheme::Atomic, 2, 35)).run(&ds);
    let ledger = &report.curve.ledger;
    assert!(ledger.consistent(), "all-zero ledger must be consistent");
    ledger.verify();
    assert_eq!(ledger.wire_bytes, 0, "shared-memory run must not ship bytes");
    assert_eq!(ledger.measured_bytes, 0);
    assert_eq!(ledger.ideal_bits, 0);
    assert_eq!(ledger.wire_bytes_by_codec, [0, 0]);
    // And the run itself did real work.
    assert!(report.updates > 0);
    assert!(report.final_loss < 1.0, "hinge loss must drop from f(0) = 1");
}

#[test]
fn tracing_does_not_change_the_single_thread_trajectory() {
    // The tentpole invariant on the fifth coordinator: recording spans
    // must not perturb the math. Run once with the recorder forced on via
    // the env switch and once with it forced off; the deterministic
    // single-thread trajectories must match bitwise. (`GSPARSE_TRACE_OUT`
    // stays unset, so no files are written either way.)
    let _env = env_lock().lock().unwrap();
    let ds = gen_svm(512, 64, 0.01, 0.9, 36);
    let run = || AsyncSvmEngine::new(cfg(Method::GSpar, UpdateScheme::Lock, 1, 36)).run(&ds);
    let prev = std::env::var("GSPARSE_TRACE").ok();
    std::env::set_var("GSPARSE_TRACE", "off");
    let baseline = run();
    std::env::set_var("GSPARSE_TRACE", "json");
    let traced = run();
    match prev {
        Some(v) => std::env::set_var("GSPARSE_TRACE", v),
        None => std::env::remove_var("GSPARSE_TRACE"),
    }
    assert_eq!(baseline.final_loss, traced.final_loss);
    assert_eq!(baseline.updates, traced.updates);
}
