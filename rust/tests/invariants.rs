//! Property-test suite over the whole library's invariants (the
//! proptest-substitute harness in `gsparse::proptest_lite`), plus failure
//! injection on the wire codec and edge cases the unit tests don't reach.

use gsparse::coding::{self, WireCodec, WireError};
use gsparse::proptest_lite::{run, Gen};
use gsparse::rngkit::{RandArray, Xoshiro256pp};
use gsparse::sparsify::{closed_form_probs, greedy_probs, sample_sparse, Compressed, SparseGrad};

/// A random structurally-valid message for codec properties: covers empty,
/// all-exact, all-shared, mixed, `d % 4 != 0`, single-coordinate, and
/// max-index (`d − 1` occupied) shapes.
fn arbitrary_message(g: &mut Gen) -> SparseGrad {
    let d = g.usize_in(1, 3000);
    let mut sg = SparseGrad::empty(d);
    sg.shared_mag = g.f32_in(0.001, 10.0);
    match g.usize_in(0, 6) {
        0 => {} // empty
        1 => {
            // all-exact, max-index included
            let mut idx = 0usize;
            while idx < d {
                sg.exact.push((idx as u32, g.f32_in(-5.0, 5.0)));
                idx += 1 + g.usize_in(0, 64);
            }
            if sg.exact.last().map(|&(i, _)| i as usize) != Some(d - 1) {
                sg.exact.push(((d - 1) as u32, 1.5));
            }
        }
        2 => {
            // single coordinate, anywhere (including d − 1)
            let i = g.usize_in(0, d) as u32;
            if g.bool() {
                sg.exact.push((i, g.f32_in(-5.0, 5.0)));
            } else {
                sg.shared.push((i, g.bool()));
            }
        }
        _ => {
            // mixed QA/QB with disjoint strictly-ascending indices
            let mut idx = 0usize;
            while idx < d {
                match g.usize_in(0, 3) {
                    0 => sg.exact.push((idx as u32, g.f32_in(-5.0, 5.0))),
                    1 => sg.shared.push((idx as u32, g.bool())),
                    _ => {}
                }
                idx += 1 + g.usize_in(0, 24);
            }
        }
    }
    sg
}

#[test]
fn prop_closed_form_dominates_any_feasible_p() {
    // Optimality spot check: the closed form's Σp must be ≤ the Σp of a
    // uniform vector meeting the same variance budget.
    run("closed form beats uniform at same variance", 64, |g: &mut Gen| {
        let d = g.usize_in(4, 500);
        let grad = g.gradient_vec(d);
        let total: f64 = grad.iter().map(|&x| (x as f64).powi(2)).sum();
        if total == 0.0 {
            return Ok(());
        }
        let eps = g.f32_in(0.05, 2.0);
        let mut p = Vec::new();
        let pv = closed_form_probs(&grad, eps, &mut p);
        // Uniform p = 1/(1+eps) over non-zeros achieves Σg²/p = (1+eps)Σg².
        let nnz = grad.iter().filter(|&&x| x != 0.0).count() as f64;
        let uniform_sum = nnz / (1.0 + eps as f64);
        if pv.expected_nnz > uniform_sum * (1.0 + 1e-5) + 1e-9 {
            return Err(format!(
                "closed form Σp {} > uniform feasible {}",
                pv.expected_nnz, uniform_sum
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_greedy_never_exceeds_variance_of_initial_scaling() {
    // Rescaling toward the target density only ever *raises* probabilities,
    // so greedy variance must be ≤ the variance of its own first pass.
    run("greedy iterations only reduce variance", 64, |g: &mut Gen| {
        let d = g.usize_in(2, 400);
        let grad = g.gradient_vec(d);
        let rho = g.f32_in(0.02, 0.9);
        let mut p0 = Vec::new();
        let v0 = greedy_probs(&grad, rho, 0, &mut p0).variance;
        let mut p2 = Vec::new();
        let v2 = greedy_probs(&grad, rho, 2, &mut p2).variance;
        if v2 > v0 * (1.0 + 1e-6) + 1e-12 {
            return Err(format!("variance rose: {v0} -> {v2}"));
        }
        Ok(())
    });
}

#[test]
fn prop_compress_decode_norm_consistency() {
    // For every method: decoded norm² equals Compressed::norm2_sq.
    run("norm2_sq matches dense decode", 48, |g: &mut Gen| {
        let d = g.usize_in(1, 300);
        let grad = g.gradient_vec(d);
        let mut rand = RandArray::new(Xoshiro256pp::seed_from_u64(g.u64()), 1 << 14);
        for &m in gsparse::config::Method::all() {
            let mut c = gsparse::api::MethodSpec::from_parts(m, 0.3, 0.5, 3).build();
            let (out, _) = c.compress(&grad, &mut rand);
            let dense = out.to_dense();
            let direct: f64 = dense.iter().map(|&v| (v as f64) * (v as f64)).sum();
            let via = out.norm2_sq();
            if (direct - via).abs() > 1e-4 * (1.0 + direct) {
                return Err(format!("{m}: norm mismatch {direct} vs {via}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_both_codecs_roundtrip_exactly() {
    // decode(encode(m)) == m for both codecs on every message shape —
    // empty, all-exact, d % 4 != 0, single-coordinate, max-index — and
    // re-encoding the decoded message reproduces the same bytes (the
    // format is canonical in both directions).
    run("both codecs roundtrip byte-for-byte", 192, |g: &mut Gen| {
        let sg = arbitrary_message(g);
        for &codec in WireCodec::all() {
            let mut buf = Vec::new();
            coding::encode_with(&sg, codec, &mut buf);
            if buf.len() != coding::encoded_len_with(&sg, codec) {
                return Err(format!("{codec}: encoded_len mismatch"));
            }
            let back = coding::decode(&buf).map_err(|e| format!("{codec}: {e}"))?;
            if back != sg {
                return Err(format!("{codec}: decoded message differs (d={})", sg.d));
            }
            let mut again = Vec::new();
            coding::encode_with(&back, codec, &mut again);
            if again != buf {
                return Err(format!("{codec}: re-encode is not byte-identical"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_entropy_never_larger_than_raw() {
    // On sorted sparse inputs (every SparseGrad is one) the entropy codec
    // must encode to at most the raw size — it can always fall back to the
    // raw encodings when Rice coding would not pay.
    run("entropy size ≤ raw size", 128, |g: &mut Gen| {
        let sg = arbitrary_message(g);
        let raw = coding::encoded_len_with(&sg, WireCodec::Raw);
        let ent = coding::encoded_len_with(&sg, WireCodec::Entropy);
        if ent > raw {
            return Err(format!("entropy {ent} > raw {raw} (d={}, nnz={})", sg.d, sg.nnz()));
        }
        let mut buf = Vec::new();
        coding::encode_with(&sg, WireCodec::Entropy, &mut buf);
        if buf.len() != ent {
            return Err("encoded_len_with(Entropy) disagrees with encode_with".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sampled_messages_roundtrip_under_entropy() {
    // The full solver + sampler pipeline (the shapes real runs produce),
    // decoded back bitwise under the entropy codec.
    run("sampled messages roundtrip (entropy)", 64, |g: &mut Gen| {
        let d = g.usize_in(1, 2000);
        let rho = g.f32_in(0.01, 1.0);
        let grad = g.gradient_vec(d);
        let mut p = Vec::new();
        let pv = greedy_probs(&grad, rho, 2, &mut p);
        let mut rand = RandArray::new(Xoshiro256pp::seed_from_u64(g.u64()), 1 << 14);
        let sg = sample_sparse(&grad, &p, pv.inv_lambda, &mut rand);
        let mut buf = Vec::new();
        coding::encode_with(&sg, WireCodec::Entropy, &mut buf);
        match coding::decode(&buf) {
            Ok(back) if back == sg => Ok(()),
            Ok(_) => Err("entropy roundtrip not identical".into()),
            Err(e) => Err(format!("entropy decode failed: {e}")),
        }
    });
}

#[test]
fn adversarial_rice_streams_reject_cleanly() {
    // Build a healthy rice-coded message, then attack each layer of its
    // hardening: truncation, gap sums past d, oversized parameters, and
    // padding that is not canonical. Every attack must yield a WireError
    // (never a panic, never a bogus Ok).
    let d = 1 << 14;
    let grad = gsparse::benchkit::skewed_gradient(d, 99, 0.3);
    let mut p = Vec::new();
    let pv = greedy_probs(&grad, 0.02, 2, &mut p);
    let mut rand = RandArray::from_seed(100, 1 << 16);
    let sg = sample_sparse(&grad, &p, pv.inv_lambda, &mut rand);
    let mut buf = Vec::new();
    let enc = coding::encode_with(&sg, WireCodec::Entropy, &mut buf);
    assert_eq!(enc, coding::Encoding::IndexedRice, "workload must pick rice");

    // Truncated streams: every strict prefix fails.
    for cut in [coding::HEADER_LEN, buf.len() / 2, buf.len() - 1] {
        assert!(
            coding::decode(&buf[..cut]).is_err(),
            "prefix of {cut}/{} decoded",
            buf.len()
        );
    }

    // Oversized Rice parameter in either header slot.
    for slot in [6usize, 7] {
        let mut bad = buf.clone();
        bad[slot] = 32;
        assert_eq!(coding::decode(&bad), Err(WireError::BadRiceParam(32)));
    }

    // Gap overflow past d: widen the final unary run so the gap sum
    // escapes the dimension (an all-ones tail also trips the quotient
    // bound — both are impossible-gap-sum rejections). A mutation can at
    // best produce a *different* valid message; silently reproducing the
    // original would mean the guards read the wrong bits.
    let mut bad = buf.clone();
    let last = bad.len() - 1;
    bad[last] = 0xFF;
    match coding::decode(&bad) {
        Err(err) => assert!(
            matches!(
                err,
                WireError::IndexOutOfBounds { .. }
                    | WireError::BadRiceStream(_)
                    | WireError::LengthMismatch { .. }
            ),
            "{err:?}"
        ),
        Ok(back) => assert_ne!(back, sg, "corrupted tail decoded to the original"),
    }
    let mut bad = buf.clone();
    bad.extend_from_slice(&[0xFF; 64]);
    let err = coding::decode(&bad).unwrap_err();
    assert!(matches!(err, WireError::LengthMismatch { .. }), "{err:?}");

    // Non-canonical padding: a trailing zero byte after the codewords.
    let mut bad = buf.clone();
    bad.push(0);
    assert!(matches!(
        coding::decode(&bad),
        Err(WireError::LengthMismatch { .. })
    ));
}

#[test]
fn entropy_codec_meets_ideal_bits_target_at_paper_scale() {
    // The PR's acceptance point: at d = 2²⁰, target density ρ = 0.01, the
    // entropy-coded message must land within 1.35× of the Theorem-4 ideal
    // bits (the raw codec sits far above it — that gap is the motivation).
    let d = 1 << 20;
    let grad = gsparse::benchkit::skewed_gradient(d, 7, 0.1);
    let mut p = Vec::new();
    let pv = greedy_probs(&grad, 0.01, 2, &mut p);
    let mut rand = RandArray::from_seed(8, 1 << 21);
    let sg = sample_sparse(&grad, &p, pv.inv_lambda, &mut rand);
    assert!(sg.nnz() > 1000, "workload sanity: nnz = {}", sg.nnz());
    let ideal = coding::ideal_message_bits(&sg) as f64;
    let mut buf = Vec::new();
    coding::encode_with(&sg, WireCodec::Entropy, &mut buf);
    let entropy_ratio = buf.len() as f64 * 8.0 / ideal;
    coding::encode_with(&sg, WireCodec::Raw, &mut buf);
    let raw_ratio = buf.len() as f64 * 8.0 / ideal;
    assert!(
        entropy_ratio <= 1.35,
        "entropy measured-bytes/ideal-bits {entropy_ratio:.3} > 1.35"
    );
    assert!(
        entropy_ratio < raw_ratio,
        "entropy ratio {entropy_ratio:.3} must beat raw {raw_ratio:.3}"
    );
}

#[test]
fn prop_wire_fuzz_never_panics() {
    // Random byte mutations of valid messages must decode to Ok or a clean
    // WireError — never panic or produce out-of-bounds structures.
    run("codec survives fuzzed mutations", 192, |g: &mut Gen| {
        let d = g.usize_in(1, 400);
        let grad = g.gradient_vec(d);
        let mut p = Vec::new();
        let pv = greedy_probs(&grad, 0.3, 2, &mut p);
        let mut rand = RandArray::new(Xoshiro256pp::seed_from_u64(g.u64()), 1 << 12);
        let sg = sample_sparse(&grad, &p, pv.inv_lambda, &mut rand);
        let codec = if g.bool() { WireCodec::Entropy } else { WireCodec::Raw };
        let mut buf = Vec::new();
        coding::encode_with(&sg, codec, &mut buf);
        // Mutate up to 4 random bytes.
        for _ in 0..g.usize_in(1, 5) {
            let pos = g.usize_in(0, buf.len());
            let val = (g.u64() & 0xFF) as u8;
            buf[pos] = val;
        }
        match coding::decode(&buf) {
            Err(_) => Ok(()),
            Ok(decoded) => {
                // If it decodes, its structure must be internally valid.
                if decoded.nnz() > decoded.d as usize {
                    return Err("decoded nnz exceeds d".into());
                }
                for &(i, _) in decoded.exact.iter() {
                    if i >= decoded.d {
                        return Err("decoded exact index out of bounds".into());
                    }
                }
                for &(i, _) in decoded.shared.iter() {
                    if i >= decoded.d {
                        return Err("decoded shared index out of bounds".into());
                    }
                }
                Ok(())
            }
        }
    });
}

#[test]
fn prop_truncation_always_rejected() {
    run("any strict prefix fails to decode", 96, |g: &mut Gen| {
        let d = g.usize_in(2, 300);
        let grad = g.gradient_vec(d);
        let mut p = Vec::new();
        let pv = greedy_probs(&grad, 0.4, 2, &mut p);
        let mut rand = RandArray::new(Xoshiro256pp::seed_from_u64(g.u64()), 1 << 12);
        let sg = sample_sparse(&grad, &p, pv.inv_lambda, &mut rand);
        let codec = if g.bool() { WireCodec::Entropy } else { WireCodec::Raw };
        let mut buf = Vec::new();
        coding::encode_with(&sg, codec, &mut buf);
        if buf.len() <= 1 {
            return Ok(());
        }
        let cut = g.usize_in(0, buf.len() - 1);
        match coding::decode(&buf[..cut]) {
            Err(_) => Ok(()),
            Ok(_) => Err(format!("prefix of {cut}/{} decoded successfully", buf.len())),
        }
    });
}

#[test]
fn prop_aggregated_mean_matches_manual() {
    use gsparse::comm::{Aggregator, NetworkModel, ReduceAlgo};
    run("allreduce = arithmetic mean of decodes", 32, |g: &mut Gen| {
        let d = g.usize_in(1, 200);
        let m = g.usize_in(1, 6);
        let mut grads = Vec::new();
        let mut rand = RandArray::new(Xoshiro256pp::seed_from_u64(g.u64()), 1 << 12);
        for _ in 0..m {
            let gv = g.gradient_vec(d);
            let mut p = Vec::new();
            let pv = greedy_probs(&gv, 0.5, 2, &mut p);
            grads.push(sample_sparse(&gv, &p, pv.inv_lambda, &mut rand));
        }
        let mut agg = Aggregator::new(NetworkModel::datacenter_10g(), ReduceAlgo::Sparse);
        let mut out = vec![0.0f32; d];
        agg.reduce(&grads, &mut out);
        let mut manual = vec![0.0f64; d];
        for sg in &grads {
            for (i, v) in sg.to_dense().into_iter().enumerate() {
                manual[i] += v as f64 / m as f64;
            }
        }
        for i in 0..d {
            if (out[i] as f64 - manual[i]).abs() > 1e-5 * (1.0 + manual[i].abs()) {
                return Err(format!("coord {i}: {} vs {}", out[i], manual[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_optimizers_preserve_finiteness() {
    use gsparse::opt::{Adam, LrSchedule, Sgd};
    run("optimizers never produce NaN on finite input", 32, |g: &mut Gen| {
        let d = g.usize_in(1, 100);
        let mut w = g.gradient_vec(d);
        let mut sgd = Sgd::new(LrSchedule::inv_t_var(g.f32_in(0.01, 2.0)));
        let mut adam = Adam::new(d, g.f32_in(0.001, 0.1));
        for _ in 0..20 {
            let grad = g.gradient_vec(d);
            sgd.step(&mut w, &grad, g.f64_in(0.5, 20.0));
            adam.step(&mut w, &grad);
        }
        if w.iter().any(|x| !x.is_finite()) {
            return Err("non-finite weight".into());
        }
        Ok(())
    });
}

#[test]
fn edge_case_d_one() {
    // Dimension 1: everything must still work.
    let grad = [0.7f32];
    let mut p = Vec::new();
    let pv = greedy_probs(&grad, 0.5, 2, &mut p);
    assert!(pv.expected_nnz > 0.0);
    let mut rand = RandArray::from_seed(1, 64);
    let sg = sample_sparse(&grad, &p, pv.inv_lambda, &mut rand);
    let mut buf = Vec::new();
    coding::encode(&sg, &mut buf);
    assert_eq!(coding::decode(&buf).unwrap(), sg);
}

#[test]
fn edge_case_all_equal_magnitudes() {
    // |g_i| all equal: greedy should give p_i = rho exactly (no dominating
    // set), and variance = Σg²/rho.
    let d = 64;
    let grad = vec![0.5f32; d];
    let mut p = Vec::new();
    let pv = greedy_probs(&grad, 0.25, 2, &mut p);
    for &pi in &p {
        assert!((pi - 0.25).abs() < 1e-5, "{pi}");
    }
    let expect_var = d as f64 * 0.25 / 0.25f64;
    assert!((pv.variance - expect_var).abs() < 1e-3 * expect_var);
}

#[test]
fn edge_case_single_huge_coordinate() {
    // One dominant coordinate that *is* essentially the whole vector: the
    // optimum is p = 1/(1+ε) — dropping it an ε-fraction of the time
    // exactly meets the variance budget (λ|g₁| = Σ|g|·|g₁|/((1+ε)Σg²) ≈
    // 1/(1+ε) < 1). Check that, and that the tiny budget pushes p → 1.
    let mut grad = vec![1e-6f32; 128];
    grad[17] = 100.0;
    let mut p = Vec::new();
    let pv = closed_form_probs(&grad, 0.1, &mut p);
    assert!(
        (p[17] - 1.0 / 1.1).abs() < 1e-3,
        "expected ≈1/(1+ε), got {}",
        p[17]
    );
    assert!(pv.variance <= 1.1 * 10_000.0 * (1.0 + 1e-5));
    let pv_tight = closed_form_probs(&grad, 1e-4, &mut p);
    assert!(p[17] > 0.999, "tight budget should keep it: {}", p[17]);
    assert!(pv_tight.variance <= (1.0 + 1e-4) * 10_000.0 * (1.0 + 1e-5));
    // Sampling still decodes with the right sign and unbiased magnitude.
    let mut rand = RandArray::from_seed(2, 1024);
    let sg = sample_sparse(&grad, &p, pv_tight.inv_lambda, &mut rand);
    let dense = sg.to_dense();
    assert!(dense[17] > 99.0, "decoded {} (g/p ≈ 100.0)", dense[17]);
}

#[test]
fn edge_case_negative_zero_and_subnormals() {
    let grad = vec![-0.0f32, f32::MIN_POSITIVE / 2.0, -1e-38, 0.5];
    let mut p = Vec::new();
    let pv = greedy_probs(&grad, 0.5, 2, &mut p);
    assert_eq!(p[0], 0.0, "-0.0 must count as zero");
    assert!(pv.variance.is_finite());
    let mut rand = RandArray::from_seed(3, 256);
    let sg = sample_sparse(&grad, &p, pv.inv_lambda, &mut rand);
    let dense = sg.to_dense();
    assert!(dense.iter().all(|x| x.is_finite()));
}

#[test]
fn compressed_variants_dim_consistency() {
    for c in [
        Compressed::Dense(vec![1.0, 2.0]),
        Compressed::Sparse(SparseGrad::empty(5)),
        Compressed::Qsgd {
            d: 3,
            norm: 1.0,
            bits: 2,
            levels: vec![0, 1, -1],
        },
        Compressed::Ternary {
            d: 4,
            scale: 0.5,
            signs: vec![0, 1, -1, 0],
        },
    ] {
        assert_eq!(c.to_dense().len(), c.dim());
        assert!(c.nnz() <= c.dim());
    }
}

// ---------------------------------------------------------------------------
// Hostile decode: one test per header/payload defense, each pinning the
// exact WireError the defense reports. Together with the Rice/batch attacks
// in golden_wire.rs this covers every WireError variant (the verifier's
// `wire-error-tests` rule fails the build if a variant loses its test).
// ---------------------------------------------------------------------------

/// A small message that deterministically encodes as `Indexed` (two exact
/// survivors in d = 1000: 16 payload bytes vs ~250 dense), giving a stable
/// byte layout to corrupt: indices at payload offsets 0 and 8.
fn indexed_fixture() -> Vec<u8> {
    let mut sg = SparseGrad::empty(1000);
    sg.shared_mag = 1.0;
    sg.exact.push((3, 1.5));
    sg.exact.push((9, -2.5));
    let mut buf = Vec::new();
    let enc = coding::encode(&sg, &mut buf);
    assert_eq!(enc, coding::Encoding::Indexed, "fixture layout assumption");
    buf
}

#[test]
fn hostile_truncated_header_is_rejected_with_length() {
    assert_eq!(coding::decode(&[]), Err(WireError::Truncated(0)));
    let buf = indexed_fixture();
    let cut = &buf[..coding::HEADER_LEN - 1];
    assert_eq!(coding::decode(cut), Err(WireError::Truncated(cut.len())));
}

#[test]
fn hostile_bad_magic_is_rejected() {
    let mut buf = indexed_fixture();
    buf[0] = b'X';
    assert_eq!(coding::decode(&buf), Err(WireError::BadMagic));
}

#[test]
fn hostile_unknown_version_is_rejected_with_value() {
    let mut buf = indexed_fixture();
    buf[4] = 9;
    assert_eq!(coding::decode(&buf), Err(WireError::BadVersion(9)));
}

#[test]
fn hostile_unknown_encoding_is_rejected_with_value() {
    let mut buf = indexed_fixture();
    buf[5] = 7;
    assert_eq!(coding::decode(&buf), Err(WireError::BadEncoding(7)));
}

#[test]
fn hostile_nonzero_reserved_bytes_are_rejected() {
    // Bytes 6–7 are the Rice parameters; on non-Rice encodings they must be
    // zero so every message has exactly one canonical byte form.
    let mut buf = indexed_fixture();
    buf[6] = 1;
    assert_eq!(coding::decode(&buf), Err(WireError::NonZeroReserved(1)));
}

#[test]
fn hostile_non_finite_shared_mag_is_rejected() {
    let mut buf = indexed_fixture();
    buf[20..24].copy_from_slice(&f32::NAN.to_le_bytes());
    assert!(matches!(
        coding::decode(&buf),
        Err(WireError::NonFiniteSharedMag(v)) if v.is_nan()
    ));
    buf[20..24].copy_from_slice(&f32::INFINITY.to_le_bytes());
    assert!(matches!(
        coding::decode(&buf),
        Err(WireError::NonFiniteSharedMag(v)) if v.is_infinite()
    ));
}

#[test]
fn hostile_unsorted_indices_are_rejected() {
    // Swap the two QA indices (payload u32s at header+0 and header+8) so
    // the stream decodes as 9, 3 — strictly-ascending order is part of the
    // canonical form, so this must be refused, not silently reordered.
    let mut buf = indexed_fixture();
    buf[coding::HEADER_LEN..coding::HEADER_LEN + 4].copy_from_slice(&9u32.to_le_bytes());
    buf[coding::HEADER_LEN + 8..coding::HEADER_LEN + 12]
        .copy_from_slice(&3u32.to_le_bytes());
    assert!(matches!(
        coding::decode(&buf),
        Err(WireError::IndicesNotSorted(_))
    ));
}
