//! End-to-end acceptance of the live telemetry plane: per-role trace dumps
//! over real TCP merge into one causally-consistent timeline (clock-aligned
//! flow arrows, non-negative tx→rx latencies), and a `/metrics` endpoint
//! scraped *mid-run* serves well-formed, monotone Prometheus text.
//!
//! Both tests mutate process environment (`GSPARSE_TRACE_OUT`,
//! `GSPARSE_METRICS_ADDR`), so they serialize on one lock and scrub the
//! variables before releasing it.

use gsparse::coordinator::dist::{self, RunPlan};
use gsparse::telemetry::{self, merge};
use gsparse::trace::TraceConfig;
use gsparse::transport::TcpTransport;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Mutex;

static ENV_LOCK: Mutex<()> = Mutex::new(());

fn small_cfg() -> RunPlan {
    RunPlan {
        workers: 2,
        rounds: 24,
        n: 128,
        d: 64,
        batch: 4,
        seed: 91,
        reg: 1.0 / (10.0 * 128.0),
        ..Default::default()
    }
}

#[test]
fn tcp_dumps_merge_into_one_causal_timeline() {
    let _guard = ENV_LOCK.lock().unwrap();
    let stem = std::env::temp_dir().join(format!("gsparse-telemetry-{}", std::process::id()));
    let stem = stem.to_str().unwrap().to_string();
    std::env::set_var("GSPARSE_TRACE_OUT", &stem);

    let cfg = RunPlan {
        trace: TraceConfig::on(),
        ..small_cfg()
    };
    let report = dist::run_threads(TcpTransport::new(), "127.0.0.1:0", &cfg).unwrap();
    std::env::remove_var("GSPARSE_TRACE_OUT");

    // The run leaves one tagged dump per role plus the server's clock
    // sidecar — the naming contract the merger and the CI guard parse.
    let tag = format!("r{}.star", cfg.rounds);
    let server = PathBuf::from(format!("{stem}.{tag}.server.trace.json"));
    let worker0 = PathBuf::from(format!("{stem}.{tag}.worker0.trace.json"));
    let worker1 = PathBuf::from(format!("{stem}.{tag}.worker1.trace.json"));
    let clock = PathBuf::from(format!("{stem}.{tag}.clock.json"));
    for p in [&server, &worker0, &worker1, &clock] {
        assert!(p.exists(), "missing dump {}", p.display());
    }
    // Same-process threads share one clock, so every estimated offset must
    // be tiny; the report surfaces the same table the sidecar holds.
    assert_eq!(report.clock_offsets_ns.len(), cfg.workers);
    for (wid, off) in &report.clock_offsets_ns {
        assert!(
            off.abs() < 1_000_000_000,
            "worker {wid} offset {off}ns is not same-host plausible"
        );
    }

    let merged = merge::merge_files(
        &[server.clone(), worker0.clone(), worker1.clone()],
        Some(clock.as_path()),
    )
    .unwrap();
    // Every communication round contributes flow-stamped frames in both
    // directions (WEIGHTS down, GRAD up) — far more links than rounds.
    assert!(
        merged.flows_linked >= cfg.rounds,
        "only {} flows linked over {} rounds",
        merged.flows_linked,
        cfg.rounds
    );
    assert_eq!(
        merged.flows_unmatched, 0,
        "every stamped frame must find its peer in the dumps"
    );
    // The headline causal invariant: after clock alignment + clamp no
    // receive precedes its send.
    assert!(
        merged.min_flow_latency_us >= 0.0,
        "negative tx->rx latency {} survived the merge",
        merged.min_flow_latency_us
    );
    // The merged doc parses as a Chrome trace and draws at least one
    // arrow per linked flow.
    assert_eq!(merged.json.matches("\"ph\":\"s\"").count(), merged.flows_linked);
    assert_eq!(merged.json.matches("\"ph\":\"f\"").count(), merged.flows_linked);

    for p in [server, worker0, worker1, clock] {
        let _ = std::fs::remove_file(p);
    }
}

fn scrape(addr: &str) -> Option<String> {
    let mut s = TcpStream::connect(addr).ok()?;
    write!(s, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").ok()?;
    let mut out = String::new();
    s.read_to_string(&mut out).ok()?;
    out.starts_with("HTTP/1.1 200").then_some(out)
}

/// `gsparse_rounds_total{worker="0"} N` → `N` from an exposition body.
fn rounds_w0(text: &str) -> Option<u64> {
    text.lines()
        .find(|l| l.starts_with("gsparse_rounds_total{worker=\"0\"}"))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse().ok())
}

#[test]
fn metrics_endpoint_serves_monotone_counters_mid_run() {
    let _guard = ENV_LOCK.lock().unwrap();
    // Reserve an ephemeral port, free it, and hand it to the run — the
    // coordinator binds it at serve() entry, well before the scrapes.
    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = probe.local_addr().unwrap().to_string();
    drop(probe);
    std::env::set_var(telemetry::METRICS_ADDR_ENV, &addr);

    let cfg = RunPlan {
        rounds: 200, // long enough that mid-run scrapes land mid-run
        ..small_cfg()
    };
    let scraper_addr = addr.clone();
    let scraper = std::thread::spawn(move || {
        let mut seen: Vec<u64> = Vec::new();
        for _ in 0..400 {
            if let Some(body) = scrape(&scraper_addr) {
                if let Some(n) = rounds_w0(&body) {
                    seen.push(n);
                }
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        seen
    });
    let report = dist::run_threads(TcpTransport::new(), "127.0.0.1:0", &cfg).unwrap();
    std::env::remove_var(telemetry::METRICS_ADDR_ENV);
    let seen = scraper.join().unwrap();

    // At least one scrape landed while the endpoint was up, every value
    // respects the final ledger, and the sequence is monotone — the
    // counter never runs backwards between scrapes.
    assert!(!seen.is_empty(), "no successful mid-run scrape");
    assert!(seen.windows(2).all(|w| w[0] <= w[1]), "counter ran backwards: {seen:?}");
    assert!(seen.iter().all(|&n| n <= cfg.rounds as u64));
    // And the final rendered registry agrees with the CommLedger exactly.
    assert!(report
        .metrics_text
        .contains(&format!("gsparse_wire_bytes_total {}", report.curve.ledger.wire_bytes)));
    assert!(report
        .metrics_text
        .contains(&format!("gsparse_rounds_total{{worker=\"0\"}} {}", cfg.rounds)));
}
