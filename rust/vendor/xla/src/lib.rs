//! Compile-everywhere stub of the `xla` PJRT bindings.
//!
//! The real crate links `xla_extension` (PJRT CPU client + HLO compiler),
//! which is unavailable in this offline image. This stub keeps the whole
//! `gsparse` crate — including the HLO-backed models and figure drivers —
//! compiling and testable: host-side [`Literal`] construction works for
//! real, while anything that would need the PJRT runtime (`compile`,
//! `execute`, HLO parsing) returns a clear [`Error`]. The artifact
//! integration tests skip themselves when `artifacts/manifest.txt` is
//! absent, so the stub never panics a test run.

use std::fmt;

/// Stub error: carries a message; converts into `anyhow::Error` via `?`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} unavailable: gsparse was built against the vendored xla stub \
         (no PJRT runtime in this image)"
    ))
}

/// Element types a [`Literal`] can hold.
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
}

/// Marker trait tying Rust scalar types to [`Data`] variants.
pub trait NativeType: Copy {
    fn wrap(values: &[Self]) -> Data;
    fn unwrap(data: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(values: &[Self]) -> Data {
        Data::F32(values.to_vec())
    }
    fn unwrap(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::F32(v) => Some(v.clone()),
            Data::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(values: &[Self]) -> Data {
        Data::I32(values.to_vec())
    }
    fn unwrap(data: &Data) -> Option<Vec<Self>> {
        match data {
            Data::I32(v) => Some(v.clone()),
            Data::F32(_) => None,
        }
    }
}

/// A host tensor literal (fully functional in the stub).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        let dims = vec![values.len() as i64];
        Literal {
            data: T::wrap(values),
            dims,
        }
    }

    /// Scalar f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal {
            data: Data::F32(vec![v]),
            dims: Vec::new(),
        }
    }

    /// Reshape; element count must match (empty dims = scalar, count 1).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    /// Extract as a typed host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| Error("literal dtype mismatch".into()))
    }

    /// Decompose a tuple literal (stub: executables never produce one).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("tuple literal decomposition"))
    }
}

/// Parsed HLO module (stub: parsing requires xla_extension).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable("HLO text parsing"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A device buffer returned by execution (stub: never materialized).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device-to-host transfer"))
    }
}

/// A compiled executable (stub: never produced by `compile`).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given inputs; `[replica][output]` buffers.
    pub fn execute<L>(&self, _inputs: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("artifact execution"))
    }
}

/// PJRT client handle. Construction succeeds so artifact-less code paths
/// (manifest probing, clear "run `make artifacts`" errors) work unchanged.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("HLO compilation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_shapes() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.element_count(), 4);
        assert!(l.reshape(&[3, 2]).is_err());
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        let s = Literal::scalar(7.5);
        assert_eq!(s.element_count(), 1);
        let i = Literal::vec1(&[5i32]).reshape(&[]).unwrap();
        assert_eq!(i.element_count(), 1);
    }

    #[test]
    fn runtime_paths_error_cleanly() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "stub-cpu");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(client.compile(&XlaComputation).is_err());
        let err = PjRtLoadedExecutable
            .execute::<Literal>(&[])
            .unwrap_err()
            .to_string();
        assert!(err.contains("stub"), "{err}");
    }
}
