//! Minimal offline stand-in for the `anyhow` crate, covering exactly the
//! surface `gsparse` uses: [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics mirror upstream where it matters to callers:
//! * `Display` prints the outermost message only;
//! * alternate `{:#}` prints the whole cause chain joined by `": "`;
//! * `Debug` prints the message plus a `Caused by:` list (what a
//!   `fn main() -> anyhow::Result<()>` error exit shows);
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`.

use std::fmt;

/// A dynamic error: an outermost message plus a cause chain.
pub struct Error {
    /// `chain[0]` is the outermost (most recent context) message.
    chain: Vec<String>,
}

impl Error {
    /// Build from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Self { chain }
    }
}

/// `anyhow::Result<T>` — a result defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e: Error = Error::from(io_err()).context("reading manifest");
        assert_eq!(e.to_string(), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
        assert_eq!(e.root_cause(), "gone");
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 10 {
                bail!("too big: {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(-1).unwrap_err().to_string(), "x must be positive, got -1");
        assert_eq!(f(11).unwrap_err().to_string(), "too big: 11");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn with_context_wraps_results() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 7)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 7: gone");
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["step 7", "gone"]);
    }
}
