//! Bench target regenerating **Figures 3 and 4** (SVRG on synthetic
//! logistic regression, both C₁ settings), plus an ablation timing of the
//! two SVRG sparsification placements (§5.1: sparsify-everything vs the
//! eq. 15 master-kept-full-gradient variant).

use gsparse::api::{MethodSpec, Session, SyncTask};
use gsparse::benchkit::{section, Bencher};
use gsparse::coordinator::sync::{OptKind, SvrgVariant};
use gsparse::data::gen_logistic;
use gsparse::figures::{fig3, fig4, ConvexFigureScale};
use gsparse::model::LogisticModel;

fn main() {
    let paper = std::env::var("GSPARSE_PAPER").is_ok();
    let scale = if paper {
        ConvexFigureScale::paper()
    } else {
        ConvexFigureScale::quick()
    };
    fig3(&scale);
    fig4(&scale);

    section("ablation: SVRG sparsification placement (§5.1)");
    let (n, d, seed) = (512usize, 1024usize, 42u64);
    let (c1, c2) = (0.6f32, 0.25f32);
    let session = Session::builder()
        .method(MethodSpec::GSpar { rho: 0.1, iters: 2 })
        .workers(4)
        .seed(seed)
        .build();
    let ds = gen_logistic(n, d, c1, c2, seed);
    let model = LogisticModel::new(1.0 / (10.0 * 1024.0));
    let task_for = |variant| SyncTask {
        epochs: 15,
        lr: 0.25,
        opt: OptKind::Svrg(variant),
        ..SyncTask::default()
    };
    for variant in [SvrgVariant::SparsifyFull, SvrgVariant::MasterFullGrad] {
        let curve = session.train_convex(&task_for(variant), &ds, &model);
        println!(
            "  {variant:?}: final loss {:.4e}, var {:.3}, bits {:.3e}",
            curve.final_loss(),
            curve.var_ratio,
            curve.ledger.ideal_bits as f64
        );
    }

    let b = Bencher::heavy();
    b.bench("svrg cell end-to-end", None, || {
        session.train_convex(&task_for(SvrgVariant::SparsifyFull), &ds, &model);
    });
}
