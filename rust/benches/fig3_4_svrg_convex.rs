//! Bench target regenerating **Figures 3 and 4** (SVRG on synthetic
//! logistic regression, both C₁ settings), plus an ablation timing of the
//! two SVRG sparsification placements (§5.1: sparsify-everything vs the
//! eq. 15 master-kept-full-gradient variant).

use gsparse::benchkit::{section, Bencher};
use gsparse::config::{ConvexConfig, Method};
use gsparse::coordinator::sync::{train_convex, OptKind, SvrgVariant, TrainOptions};
use gsparse::data::gen_logistic;
use gsparse::figures::{fig3, fig4, ConvexFigureScale};
use gsparse::model::LogisticModel;

fn main() {
    let paper = std::env::var("GSPARSE_PAPER").is_ok();
    let scale = if paper {
        ConvexFigureScale::paper()
    } else {
        ConvexFigureScale::quick()
    };
    fig3(&scale);
    fig4(&scale);

    section("ablation: SVRG sparsification placement (§5.1)");
    let cfg = ConvexConfig {
        n: 512,
        d: 1024,
        epochs: 15,
        method: Method::GSpar,
        lr: 0.25,
        ..Default::default()
    };
    let ds = gen_logistic(cfg.n, cfg.d, cfg.c1, cfg.c2, cfg.seed);
    let model = LogisticModel::new(cfg.reg);
    for variant in [SvrgVariant::SparsifyFull, SvrgVariant::MasterFullGrad] {
        let opts = TrainOptions {
            opt: OptKind::Svrg(variant),
            ..Default::default()
        };
        let curve = train_convex(&cfg, &opts, &ds, &model);
        println!(
            "  {variant:?}: final loss {:.4e}, var {:.3}, bits {:.3e}",
            curve.final_loss(),
            curve.var_ratio,
            curve.ledger.ideal_bits as f64
        );
    }

    let b = Bencher::heavy();
    b.bench("svrg cell end-to-end", None, || {
        let opts = TrainOptions {
            opt: OptKind::Svrg(SvrgVariant::SparsifyFull),
            ..Default::default()
        };
        train_convex(&cfg, &opts, &ds, &model);
    });
}
