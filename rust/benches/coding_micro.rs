//! Microbenchmarks for the §3.3 wire codec: encode, decode, and the
//! all-reduce merge, across densities (both encodings get exercised).

use gsparse::benchkit::{black_box, section, Bencher};
use gsparse::coding;
use gsparse::comm::{Aggregator, NetworkModel, ReduceAlgo};
use gsparse::rngkit::{RandArray, Xoshiro256pp};
use gsparse::sparsify::{greedy_probs, sample_sparse, SparseGrad};

fn message(d: usize, rho: f32, seed: u64) -> SparseGrad {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let g: Vec<f32> = (0..d).map(|_| (rng.next_gaussian() * 0.3) as f32).collect();
    let mut p = Vec::new();
    let pv = greedy_probs(&g, rho, 2, &mut p);
    let mut rand = RandArray::from_seed(seed ^ 1, 1 << 20);
    sample_sparse(&g, &p, pv.inv_lambda, &mut rand)
}

fn main() {
    let b = Bencher::default();

    section("encode / decode (d = 262144)");
    let d = 262_144;
    for rho in [0.01f32, 0.05, 0.5] {
        let sg = message(d, rho, 10);
        let mut buf = Vec::new();
        let enc = coding::encode(&sg, &mut buf);
        b.bench(
            &format!("encode rho={rho} ({enc:?}, {} B)", buf.len()),
            Some(sg.nnz() as u64),
            || {
                black_box(coding::encode(black_box(&sg), &mut buf));
            },
        );
        b.bench(&format!("decode rho={rho}"), Some(sg.nnz() as u64), || {
            black_box(coding::decode(black_box(&buf)).unwrap());
        });
    }

    section("all-reduce merge of M=4 encoded messages (d = 262144)");
    for rho in [0.01f32, 0.05] {
        let grads: Vec<SparseGrad> = (0..4).map(|m| message(d, rho, 20 + m)).collect();
        let mut out = vec![0.0f32; d];
        for algo in [ReduceAlgo::Naive, ReduceAlgo::Sparse] {
            let mut agg = Aggregator::new(NetworkModel::datacenter_10g(), algo);
            b.bench(&format!("reduce {algo:?} rho={rho}"), Some(d as u64), || {
                black_box(agg.reduce(black_box(&grads), &mut out));
            });
        }
    }
}
