//! Microbenchmarks for the §3.3 wire codec: encode, decode, and the
//! all-reduce merge, across densities (all encodings get exercised) and
//! across both [`WireCodec`]s. Besides timings, this writes the
//! **measured-bytes / ideal-bits** ratio per (codec, d, ρ) point to
//! `BENCH_coding.json` (override with `GSPARSE_BENCH_OUT`) — the trajectory
//! that shows the entropy coder closing the gap to the Theorem-4 bound;
//! the acceptance point is d = 2²⁰, ρ = 0.01.

use gsparse::benchkit::{black_box, section, Bencher, JsonReport};
use gsparse::coding::{self, WireCodec};
use gsparse::comm::{Aggregator, NetworkModel, ReduceAlgo};
use gsparse::rngkit::{RandArray, Xoshiro256pp};
use gsparse::sparsify::{greedy_probs, sample_sparse, SparseGrad};

fn message(d: usize, rho: f32, seed: u64) -> SparseGrad {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let g: Vec<f32> = (0..d).map(|_| (rng.next_gaussian() * 0.3) as f32).collect();
    let mut p = Vec::new();
    let pv = greedy_probs(&g, rho, 2, &mut p);
    let mut rand = RandArray::from_seed(seed ^ 1, 1 << 22);
    sample_sparse(&g, &p, pv.inv_lambda, &mut rand)
}

fn main() {
    let b = Bencher::default();
    let mut report = JsonReport::new();

    for codec in [WireCodec::Raw, WireCodec::Entropy] {
        section(&format!("encode / decode, codec = {codec} (d = 262144)"));
        let d = 262_144;
        for rho in [0.01f32, 0.05, 0.5] {
            let sg = message(d, rho, 10);
            let mut buf = Vec::new();
            let enc = coding::encode_with(&sg, codec, &mut buf);
            let s = b.bench(
                &format!("encode[{codec}] rho={rho} ({enc:?}, {} B)", buf.len()),
                Some(sg.nnz() as u64),
                || {
                    black_box(coding::encode_with(black_box(&sg), codec, &mut buf));
                },
            );
            report.push(&s);
            let s = b.bench(
                &format!("decode[{codec}] rho={rho}"),
                Some(sg.nnz() as u64),
                || {
                    black_box(coding::decode(black_box(&buf)).unwrap());
                },
            );
            report.push(&s);
        }
    }

    // ---- the gap to the ideal-bit model, per codec ---------------------
    section("measured bytes / Theorem-4 ideal bits");
    for (d, rho) in [(1usize << 20, 0.01f32), (1 << 18, 0.01), (1 << 16, 0.05)] {
        let sg = message(d, rho, 30);
        let ideal_bits = coding::ideal_message_bits(&sg);
        for codec in [WireCodec::Raw, WireCodec::Entropy] {
            let mut buf = Vec::new();
            coding::encode_with(&sg, codec, &mut buf);
            let ratio = (buf.len() as f64 * 8.0) / ideal_bits as f64;
            println!(
                "  codec={codec:<7} d=2^{:<2} rho={rho:<5} nnz={:<7} \
                 measured {:>9} B  ideal {:>9} bits  ratio {ratio:.3}",
                d.trailing_zeros(),
                sg.nnz(),
                buf.len(),
                ideal_bits,
            );
            report.push_metric(
                &format!("bytes_over_ideal_bits/{codec}/d{d}_rho{rho}"),
                ratio,
            );
        }
    }

    section("all-reduce merge of M=4 encoded messages (d = 262144)");
    let d = 262_144;
    for rho in [0.01f32, 0.05] {
        let grads: Vec<SparseGrad> = (0..4).map(|m| message(d, rho, 20 + m)).collect();
        let mut out = vec![0.0f32; d];
        for algo in [ReduceAlgo::Naive, ReduceAlgo::Sparse] {
            let mut agg = Aggregator::new(NetworkModel::datacenter_10g(), algo);
            let s = b.bench(&format!("reduce {algo:?} rho={rho}"), Some(d as u64), || {
                black_box(agg.reduce(black_box(&grads), &mut out));
            });
            report.push(&s);
        }
    }

    let out_path =
        std::env::var("GSPARSE_BENCH_OUT").unwrap_or_else(|_| "BENCH_coding.json".to_string());
    match report.write(&out_path) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}
