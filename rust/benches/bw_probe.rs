use gsparse::benchkit::{black_box, Bencher};
fn main() {
    let d = 262_144usize;
    let g: Vec<f32> = (0..d).map(|i| (i as f32).sin()).collect();
    let b = Bencher::default();
    b.bench("norm1 single pass", Some(d as u64), || {
        black_box(gsparse::tensor::norm1(black_box(&g)));
    });
    let mut p = vec![0.0f32; d];
    b.bench("copy pass", Some(d as u64), || {
        p.copy_from_slice(black_box(&g));
        black_box(&p);
    });
}
