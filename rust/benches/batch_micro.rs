//! Batched-vs-per-layer microbench: for a CNN-shaped layer list it measures
//! (a) wire bytes per model update under both codecs, (b) transport frames
//! and measured framed bytes per cluster round, and (c) the engine-level
//! wall time of one fused batch invocation vs one invocation per layer.
//! Writes `BENCH_batch.json` (override with `GSPARSE_BENCH_OUT`); CI
//! uploads it next to the other bench JSONs.

use gsparse::api::{MethodSpec, Session};
use gsparse::benchkit::{black_box, section, Bencher, JsonReport};
use gsparse::coding::WireCodec;
use gsparse::rngkit::RandArray;
use gsparse::sparsify::{BatchCompressEngine, CompressEngine, SparseGrad};

/// A §5.2-shaped layer list: conv stacks + a large FC layer.
const DIMS: [usize; 6] = [1 << 16, 3 << 15, 1 << 15, 1 << 14, 1 << 14, 1 << 15];
const RHO: f32 = 0.01;

fn layer_list() -> Vec<Vec<f32>> {
    DIMS.iter()
        .enumerate()
        .map(|(l, &d)| gsparse::benchkit::skewed_gradient(d, 31 + l as u64, 0.1))
        .collect()
}

fn bench_wire_bytes(report: &mut JsonReport) {
    section("wire bytes per model update: WireBatch vs per-layer messages");
    let layers = layer_list();
    let refs: Vec<&[f32]> = layers.iter().map(|g| g.as_slice()).collect();
    let mut engine = BatchCompressEngine::greedy(RHO, 2);
    let rand = RandArray::from_seed(5, 1 << 19);
    let (mut outs, mut pvs, mut wire) = (Vec::new(), Vec::new(), Vec::new());
    for codec in [WireCodec::Raw, WireCodec::Entropy] {
        let mut rand2 = rand.clone();
        engine.compress_batch_into(&refs, codec, &mut rand2, &mut outs, &mut wire, &mut pvs);
        let batch = wire.len();
        let singles: usize = outs
            .iter()
            .map(|sg| gsparse::coding::encoded_len_with(sg, codec))
            .sum();
        println!(
            "  codec={codec:<7} L={} d_total={} batch {batch:>8} B  \
             per-layer {singles:>8} B  saved {:>6} B/round/worker",
            DIMS.len(),
            DIMS.iter().sum::<usize>(),
            singles as i64 - batch as i64,
        );
        report.push_metric(&format!("batch_bytes/{codec}"), batch as f64);
        report.push_metric(&format!("per_layer_bytes/{codec}"), singles as f64);
        report.push_metric(
            &format!("batch_over_per_layer/{codec}"),
            batch as f64 / singles.max(1) as f64,
        );
    }
}

fn bench_cluster_frames(report: &mut JsonReport) {
    section("cluster round: frames + measured bytes, batched vs per-layer");
    let workers = 2usize;
    let grads: Vec<Vec<Vec<f32>>> = (0..workers)
        .map(|w| {
            DIMS.iter()
                .enumerate()
                .map(|(l, &d)| {
                    gsparse::benchkit::skewed_gradient(d, (w * 13 + l) as u64, 0.1)
                })
                .collect()
        })
        .collect();
    for codec in [WireCodec::Raw, WireCodec::Entropy] {
        for batch in [false, true] {
            let mut cluster = Session::builder()
                .method(MethodSpec::GSpar { rho: RHO, iters: 2 })
                .codec(codec)
                .workers(workers)
                .seed(77)
                .batch_layers(batch)
                .build()
                .cluster(&DIMS);
            let rounds = 4u64;
            for _ in 0..rounds {
                black_box(cluster.round(&grads));
            }
            let label = if batch { "batched" } else { "per_layer" };
            let frames = cluster.frames_received() - workers as u64; // minus hellos
            println!(
                "  codec={codec:<7} {label:<9} frames/round {:>4}  wire {:>9} B  \
                 measured {:>9} B",
                frames / rounds,
                cluster.ledger.wire_bytes / rounds,
                cluster.ledger.measured_bytes / rounds,
            );
            report.push_metric(
                &format!("frames_per_round/{codec}/{label}"),
                (frames / rounds) as f64,
            );
            report.push_metric(
                &format!("wire_bytes_per_round/{codec}/{label}"),
                (cluster.ledger.wire_bytes / rounds) as f64,
            );
            report.push_metric(
                &format!("measured_bytes_per_round/{codec}/{label}"),
                (cluster.ledger.measured_bytes / rounds) as f64,
            );
        }
    }
}

fn bench_engine_time(report: &mut JsonReport) {
    section("engine invocation: one fused batch vs one call per layer");
    let b = Bencher::default();
    let layers = layer_list();
    let refs: Vec<&[f32]> = layers.iter().map(|g| g.as_slice()).collect();
    let total: u64 = DIMS.iter().sum::<usize>() as u64;

    let mut batch_engine = BatchCompressEngine::greedy(RHO, 2);
    let mut rand = RandArray::from_seed(6, 1 << 19);
    let (mut outs, mut pvs, mut wire) = (Vec::new(), Vec::new(), Vec::new());
    let s = b.bench("batched compress+encode (6 layers)", Some(total), || {
        batch_engine.compress_batch_into(
            black_box(&refs),
            WireCodec::Entropy,
            &mut rand,
            &mut outs,
            &mut wire,
            &mut pvs,
        );
    });
    report.push(&s);

    let mut engines: Vec<CompressEngine> = DIMS
        .iter()
        .map(|_| CompressEngine::greedy(RHO, 2))
        .collect();
    let mut rand = RandArray::from_seed(6, 1 << 19);
    let mut sgs: Vec<SparseGrad> = DIMS.iter().map(|_| SparseGrad::empty(0)).collect();
    let mut wires: Vec<Vec<u8>> = DIMS.iter().map(|_| Vec::new()).collect();
    let s = b.bench("per-layer compress+encode (6 calls)", Some(total), || {
        for ((engine, g), (sg, w)) in engines
            .iter_mut()
            .zip(refs.iter())
            .zip(sgs.iter_mut().zip(wires.iter_mut()))
        {
            black_box(engine.compress_into_with(g, WireCodec::Entropy, &mut rand, sg, w));
        }
    });
    report.push(&s);
}

fn main() {
    let mut report = JsonReport::new();
    bench_wire_bytes(&mut report);
    bench_cluster_frames(&mut report);
    bench_engine_time(&mut report);
    let out_path =
        std::env::var("GSPARSE_BENCH_OUT").unwrap_or_else(|_| "BENCH_batch.json".to_string());
    match report.write(&out_path) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}
