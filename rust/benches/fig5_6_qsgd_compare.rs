//! Bench target regenerating **Figures 5 and 6** (GSpar vs QSGD(b) vs dense
//! on the coding-length x-axis), plus a bits-per-element summary table.

use gsparse::figures::{fig5, fig6, ConvexFigureScale};

fn main() {
    let paper = std::env::var("GSPARSE_PAPER").is_ok();
    let scale = if paper {
        ConvexFigureScale::paper()
    } else {
        ConvexFigureScale::quick()
    };
    fig5(&scale);
    fig6(&scale);
}
