//! Error-feedback + local-step microbench: (a) the convergence headline —
//! top-k with residual memory vs the unbiased sparsifier at matched wire
//! bytes on the deterministic logreg workload; (b) bytes-per-epoch as a
//! function of the local-step period H; (c) the adapter's per-call
//! overhead around a compressor. Writes `BENCH_feedback.json` (override
//! with `GSPARSE_BENCH_OUT`); CI uploads it next to the other bench JSONs.

use gsparse::api::{MethodSpec, Session, SyncTask};
use gsparse::benchkit::{section, Bencher, JsonReport};
use gsparse::coordinator::sync::OptKind;
use gsparse::data::gen_logistic;
use gsparse::feedback::{FeedbackConfig, WithFeedback};
use gsparse::model::LogisticModel;
use gsparse::rngkit::RandArray;
use gsparse::sparsify::{Compressed, Compressor, SparseGrad, TopKCompressor};

fn bench_convergence_at_matched_bytes(report: &mut JsonReport) {
    section("top-k ρ=0.001: error feedback vs plain vs unbiased GSpar (equal-ish bytes)");
    let ds = gen_logistic(256, 2048, 0.6, 0.25, 515);
    let model = LogisticModel::new(1.0 / (10.0 * 256.0));
    let task = SyncTask {
        batch: 8,
        epochs: 100,
        lr: 1.0,
        opt: OptKind::SgdInvT,
        ..SyncTask::default()
    };
    let run = |label: &str, spec: MethodSpec, feedback: bool| {
        let mut builder = Session::builder().method(spec).workers(4).seed(515);
        if feedback {
            builder = builder.feedback(FeedbackConfig::default());
        }
        let curve = builder.build().train_convex(&task, &ds, &model);
        println!(
            "  {label:<18} final loss {:.5}  wire {:>9} B  measured {:>9} B",
            curve.final_loss(),
            curve.ledger.wire_bytes,
            curve.ledger.measured_bytes
        );
        curve
    };
    let plain = run("topk", MethodSpec::TopK { rho: 0.001 }, false);
    let fb = run("topk+feedback", MethodSpec::TopK { rho: 0.001 }, true);
    // The unbiased method at a density whose wire cost lands in the same
    // ballpark (GSpar messages carry an extra shared-magnitude structure).
    let gspar = run("gspar", MethodSpec::GSpar { rho: 0.001, iters: 2 }, false);
    report.push_metric("final_loss/topk_rho0.001", plain.final_loss());
    report.push_metric("final_loss/topk_feedback_rho0.001", fb.final_loss());
    report.push_metric("final_loss/gspar_rho0.001", gspar.final_loss());
    report.push_metric("wire_bytes/topk_rho0.001", plain.ledger.wire_bytes as f64);
    report.push_metric("wire_bytes/topk_feedback_rho0.001", fb.ledger.wire_bytes as f64);
    report.push_metric("wire_bytes/gspar_rho0.001", gspar.ledger.wire_bytes as f64);
    report.push_metric(
        "loss_ratio/feedback_over_plain",
        fb.final_loss() / plain.final_loss(),
    );
}

fn bench_bytes_per_epoch_vs_h(report: &mut JsonReport) {
    section("bytes per epoch vs local-step period H (GSpar ρ=0.1, 4 workers)");
    let ds = gen_logistic(256, 1024, 0.6, 0.25, 77);
    let model = LogisticModel::new(1.0 / (10.0 * 256.0));
    let epochs = 16usize;
    let task = SyncTask {
        batch: 8,
        epochs,
        lr: 1.0,
        ..SyncTask::default()
    };
    for h in [1usize, 2, 4, 8] {
        let curve = Session::builder()
            .method(MethodSpec::GSpar { rho: 0.1, iters: 2 })
            .workers(4)
            .seed(77)
            .local_steps(h)
            .build()
            .train_convex(&task, &ds, &model);
        let wire_per_epoch = curve.ledger.wire_bytes as f64 / epochs as f64;
        let measured_per_epoch = curve.ledger.measured_bytes as f64 / epochs as f64;
        println!(
            "  H={h}: wire {wire_per_epoch:>10.0} B/epoch  measured {measured_per_epoch:>10.0} \
             B/epoch  frames {}  final loss {:.5}",
            curve.ledger.measured_frames,
            curve.final_loss()
        );
        report.push_metric(&format!("wire_bytes_per_epoch/H={h}"), wire_per_epoch);
        report.push_metric(&format!("measured_bytes_per_epoch/H={h}"), measured_per_epoch);
        report.push_metric(&format!("measured_frames/H={h}"), curve.ledger.measured_frames as f64);
        report.push_metric(&format!("final_loss/H={h}"), curve.final_loss());
    }
}

fn bench_adapter_overhead(report: &mut JsonReport) {
    section("WithFeedback adapter overhead (top-k, d = 2^16)");
    let d = 1 << 16;
    let g = gsparse::benchkit::skewed_gradient(d, 9, 0.1);
    let bencher = Bencher::new(48, 8);

    let mut plain = TopKCompressor::new(0.01);
    let mut rand = RandArray::from_seed(10, 1 << 18);
    let mut msg = Compressed::Sparse(SparseGrad::empty(d));
    let s = bencher.bench("topk/compress_into", Some(d as u64), || {
        plain.compress_into(&g, &mut rand, &mut msg);
    });
    report.push(&s);
    let plain_s = s.mean.as_secs_f64();

    let mut fb = WithFeedback::new(TopKCompressor::new(0.01));
    let s = bencher.bench("topk+feedback/compress_into", Some(d as u64), || {
        fb.compress_into(&g, &mut rand, &mut msg);
    });
    report.push(&s);
    let ratio = s.mean.as_secs_f64() / plain_s.max(1e-12);
    println!("  adapter overhead: {ratio:.2}x over the bare compressor");
    report.push_metric("feedback_overhead_ratio", ratio);
}

fn main() {
    let mut report = JsonReport::new();
    bench_convergence_at_matched_bytes(&mut report);
    bench_bytes_per_epoch_vs_h(&mut report);
    bench_adapter_overhead(&mut report);
    let out = std::env::var("GSPARSE_BENCH_OUT").unwrap_or_else(|_| "BENCH_feedback.json".into());
    report.write(&out).expect("write bench json");
    println!("wrote {out}");
}
