//! Bench target for the theory section: Lemma 3 (expected sparsity) and
//! Theorem 4 (coding length) bound-vs-measured sweep, plus greedy-vs-exact
//! variance optimality at matched sparsity.

use gsparse::benchkit::section;
use gsparse::rngkit::Xoshiro256pp;
use gsparse::sparsify::{closed_form_probs, greedy_probs};

fn main() {
    gsparse::figures::theory_bounds();

    section("greedy vs closed-form: variance at matched expected sparsity");
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    println!(
        "{:>8} {:>8} | {:>12} {:>12} {:>8}",
        "d", "rho", "greedy var", "optimal var", "ratio"
    );
    for &d in &[1024usize, 8192] {
        for &rho in &[0.02f32, 0.1, 0.3] {
            let g: Vec<f32> = (0..d)
                .map(|_| {
                    let u = rng.next_f32();
                    if u < 0.1 {
                        (rng.next_gaussian() * 4.0) as f32
                    } else {
                        (rng.next_gaussian() * 0.05) as f32
                    }
                })
                .collect();
            let mut p = Vec::new();
            let greedy = greedy_probs(&g, rho, 2, &mut p);
            // Bisect closed-form eps to the same expected nnz.
            let (mut lo, mut hi) = (0.0f32, 100.0f32);
            let mut pc = Vec::new();
            for _ in 0..48 {
                let mid = 0.5 * (lo + hi);
                if closed_form_probs(&g, mid, &mut pc).expected_nnz > greedy.expected_nnz {
                    lo = mid;
                } else {
                    hi = mid;
                }
            }
            let exact = closed_form_probs(&g, 0.5 * (lo + hi), &mut pc);
            println!(
                "{d:>8} {rho:>8.2} | {:>12.4} {:>12.4} {:>8.4}",
                greedy.variance,
                exact.variance,
                greedy.variance / exact.variance
            );
        }
    }
}
