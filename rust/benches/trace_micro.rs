//! Trace-instrumentation microbench: the cost of one span / counter record
//! (hot-path price of observability), the inert cost when tracing is off,
//! and the ISSUE acceptance workload — a full compress+encode round at
//! d = 2^20, ρ = 0.01 traced vs. untraced, whose overhead ratio the CI
//! trace guard pins at ≤ 5%. Writes `BENCH_trace.json` (override with
//! `GSPARSE_BENCH_OUT`).

use gsparse::benchkit::{black_box, section, Bencher, JsonReport};
use gsparse::rngkit::RandArray;
use gsparse::sparsify::{CompressEngine, SparseGrad};
use gsparse::trace::{self, Stage, TraceConfig};
use std::time::Instant;

const ROUND_D: usize = 1 << 20;
const ROUND_RHO: f32 = 0.01;
const ROUND_REPS: usize = 8;

fn bench_span_costs(report: &mut JsonReport) {
    section("span record cost");
    let bench = Bencher::default();

    // Inert path: no recorder exists process-wide, so a span is one relaxed
    // atomic load plus a no-op drop.
    let s = bench.bench("span inert (tracing off)", None, || {
        let mut sp = trace::span(black_box(Stage::Solve));
        sp.bytes(4096);
    });
    report.push(&s);

    // Hot path: recorder installed on this thread; every span is two clock
    // reads plus one ring write (overwriting in place once the ring fills —
    // exactly the steady state of a long traced run).
    let rec = trace::Recorder::new(&TraceConfig::on()).expect("recorder");
    let guard = trace::install(&rec, 0);
    trace::set_round(1);
    let s = bench.bench("span record (tracing on)", None, || {
        let mut sp = trace::span(black_box(Stage::Solve));
        sp.bytes(4096);
    });
    report.push(&s);
    let span_ns = s.mean.as_secs_f64() * 1e9;
    let s = bench.bench("counter record (tracing on)", None, || {
        trace::counter(black_box(Stage::FrameTx), 128);
    });
    report.push(&s);

    // Export cost (off the hot path, but the guard wants it tracked): drain
    // the bench's ring and render Chrome JSON.
    let events = rec.drain();
    let n_events = events.len().max(1);
    let t0 = Instant::now();
    let json = trace::chrome_trace_json(&events);
    let export_s = t0.elapsed().as_secs_f64();
    black_box(json.len());
    drop(guard);

    report.push_metric("span_record_ns", span_ns);
    report.push_metric(
        "chrome_export_ns_per_event",
        export_s * 1e9 / n_events as f64,
    );
    println!(
        "span {span_ns:.1} ns; chrome export {:.1} ns/event over {n_events} events",
        export_s * 1e9 / n_events as f64
    );
}

fn bench_registry_costs(report: &mut JsonReport) {
    section("telemetry registry cost");
    let bench = Bencher::default();
    let reg = gsparse::telemetry::Registry::new();
    let c = reg.counter("bench_rounds_total", "bench", &[("worker", "0")]);
    let g = reg.gauge("bench_straggler_ratio", "bench", &[]);
    let h = reg.histogram(
        "bench_round_latency_seconds",
        "bench",
        &[("worker", "0")],
        &[1e-4, 1e-3, 1e-2, 0.1, 1.0],
    );
    // The whole per-round metrics update a coordinator performs: one
    // counter bump, one gauge store, one histogram observation.
    let s = bench.bench("registry update (counter+gauge+histogram)", None, || {
        c.inc();
        g.set(black_box(1.25));
        h.observe(black_box(0.004));
    });
    report.push(&s);
    let update_ns = s.mean.as_secs_f64() * 1e9;

    // Scrape-side price (responder thread only, never the hot path).
    let reps = 1000usize;
    let t0 = Instant::now();
    for _ in 0..reps {
        black_box(reg.render().len());
    }
    let render_ns = t0.elapsed().as_secs_f64() * 1e9 / reps as f64;
    report.push_metric("registry_update_ns", update_ns);
    report.push_metric("registry_render_ns", render_ns);
    println!("registry update {update_ns:.1} ns; render {render_ns:.1} ns/scrape");
}

/// Average seconds per compress+encode round (solve → sample → wire encode,
/// the fully instrumented engine path) over `ROUND_REPS` repetitions.
fn round_s(
    engine: &mut CompressEngine,
    g: &[f32],
    rand: &mut RandArray,
    out: &mut SparseGrad,
    wire: &mut Vec<u8>,
) -> f64 {
    let t0 = Instant::now();
    for _ in 0..ROUND_REPS {
        engine.compress_into(g, rand, out, wire);
        black_box(wire.len());
    }
    t0.elapsed().as_secs_f64() / ROUND_REPS as f64
}

fn bench_traced_round(report: &mut JsonReport) {
    section(&format!(
        "traced vs untraced round: d = 2^20, rho = {ROUND_RHO}"
    ));
    let g = gsparse::benchkit::skewed_gradient(ROUND_D, 3, 0.1);
    let mut engine = CompressEngine::greedy(ROUND_RHO, 2);
    engine.reserve(ROUND_D);
    let mut rand = RandArray::from_seed(4, 1 << 21);
    let mut out = SparseGrad::empty(ROUND_D);
    let mut wire = Vec::new();

    // Warmup grows every scratch buffer to its plateau.
    for _ in 0..2 {
        engine.compress_into(&g, &mut rand, &mut out, &mut wire);
    }
    let untraced_s = round_s(&mut engine, &g, &mut rand, &mut out, &mut wire);

    let rec = trace::Recorder::new(&TraceConfig::on()).expect("recorder");
    let guard = trace::install(&rec, 0);
    engine.compress_into(&g, &mut rand, &mut out, &mut wire); // traced warmup
    let events_per_round = rec.drain().len();
    let traced_s = round_s(&mut engine, &g, &mut rand, &mut out, &mut wire);
    drop(guard);

    let overhead_x = traced_s / untraced_s;
    println!(
        "untraced {:.3} ms  traced {:.3} ms  ({overhead_x:.4}x, {events_per_round} events/round)",
        untraced_s * 1e3,
        traced_s * 1e3,
    );
    report.push_metric("round_untraced_s", untraced_s);
    report.push_metric("round_traced_s", traced_s);
    report.push_metric("round_trace_overhead_x", overhead_x);
    report.push_metric("round_events_per_round", events_per_round as f64);

    // Full telemetry on: tracing plus the per-round registry updates the
    // dist coordinator performs (counter + gauge + latency histogram).
    // The CI trace guard pins this ratio at ≤ 5% overhead too.
    let reg = gsparse::telemetry::Registry::new();
    let rounds = reg.counter("bench_rounds_total", "bench", &[("worker", "0")]);
    let version = reg.gauge("bench_weight_version", "bench", &[]);
    let latency = reg.histogram(
        "bench_round_latency_seconds",
        "bench",
        &[("worker", "0")],
        &[1e-4, 1e-3, 1e-2, 0.1, 1.0],
    );
    let rec = trace::Recorder::new(&TraceConfig::on()).expect("recorder");
    let guard = trace::install(&rec, 0);
    let t0 = Instant::now();
    for i in 0..ROUND_REPS {
        let r0 = Instant::now();
        engine.compress_into(&g, &mut rand, &mut out, &mut wire);
        black_box(wire.len());
        rounds.inc();
        version.set(i as f64);
        latency.observe(r0.elapsed().as_secs_f64());
    }
    let telemetry_s = t0.elapsed().as_secs_f64() / ROUND_REPS as f64;
    drop(guard);
    let telemetry_x = telemetry_s / untraced_s;
    println!(
        "traced+metrics {:.3} ms  ({telemetry_x:.4}x untraced)",
        telemetry_s * 1e3
    );
    report.push_metric("round_telemetry_s", telemetry_s);
    report.push_metric("round_telemetry_overhead_x", telemetry_x);
}

fn main() {
    let mut report = JsonReport::new();
    bench_span_costs(&mut report);
    bench_registry_costs(&mut report);
    bench_traced_round(&mut report);
    let out_path =
        std::env::var("GSPARSE_BENCH_OUT").unwrap_or_else(|_| "BENCH_trace.json".to_string());
    match report.write(&out_path) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}
