//! Microbenchmarks for the L3 hot path: probability computation (greedy &
//! closed-form), Bernoulli sampling, and every baseline compressor, across
//! gradient dimensions. These are the numbers EXPERIMENTS.md §Perf tracks.

use gsparse::benchkit::{black_box, section, Bencher};
use gsparse::config::Method;
use gsparse::rngkit::{RandArray, Xoshiro256pp};
use gsparse::sparsify::{self, closed_form_probs, greedy_probs, sample_sparse};

fn gradient(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    (0..d)
        .map(|_| {
            let u = rng.next_f32();
            if u < 0.1 {
                (rng.next_gaussian() * 4.0) as f32
            } else {
                (rng.next_gaussian() * 0.05) as f32
            }
        })
        .collect()
}

fn main() {
    let b = Bencher::default();

    section("greedy probability computation (Algorithm 3, 2 iters)");
    for d in [2048usize, 16_384, 262_144, 1 << 21] {
        let g = gradient(d, 1);
        let mut p = Vec::new();
        b.bench(&format!("greedy_probs d={d}"), Some(d as u64), || {
            black_box(greedy_probs(black_box(&g), 0.05, 2, &mut p));
        });
    }

    section("closed-form probability computation (Algorithm 2)");
    for d in [2048usize, 16_384, 262_144] {
        let g = gradient(d, 2);
        let mut p = Vec::new();
        b.bench(&format!("closed_form d={d}"), Some(d as u64), || {
            black_box(closed_form_probs(black_box(&g), 0.5, &mut p));
        });
    }

    section("Bernoulli sampling + rescale");
    for d in [2048usize, 262_144] {
        let g = gradient(d, 3);
        let mut p = Vec::new();
        let pv = greedy_probs(&g, 0.05, 2, &mut p);
        let mut rand = RandArray::from_seed(4, 1 << 22);
        b.bench(&format!("sample_sparse d={d}"), Some(d as u64), || {
            black_box(sample_sparse(black_box(&g), &p, pv.inv_lambda, &mut rand));
        });
    }

    section("full compress step per method (d = 262144, rho = 0.05)");
    let d = 262_144;
    let g = gradient(d, 5);
    let mut rand = RandArray::from_seed(6, 1 << 22);
    for &m in Method::all() {
        let mut c = sparsify::build(m, 0.05, 0.5, 4);
        b.bench(&format!("compress {m}"), Some(d as u64), || {
            black_box(c.compress(black_box(&g), &mut rand));
        });
    }
}
