//! Microbenchmarks for the L3 hot path: probability computation (greedy &
//! closed-form, sort-based vs. selection-based), Bernoulli sampling, the
//! fused allocation-free engine (sequential and sharded), and every baseline
//! compressor, across gradient dimensions. These are the numbers
//! EXPERIMENTS.md §Perf tracks; a machine-readable copy is written to
//! `BENCH_sparsify.json` (override the path with `GSPARSE_BENCH_OUT`) so the
//! perf trajectory is tracked from PR to PR.

use gsparse::benchkit::{
    allocation_count, black_box, section, skewed_gradient, Bencher, CountingAllocator, JsonReport,
};
use gsparse::config::Method;
use gsparse::rngkit::RandArray;
use gsparse::sparsify::{
    self, closed_form_probs_sorted, closed_form_probs_with, greedy_probs, sample_sparse,
    CompressEngine, SelectScratch, SparseGrad,
};

// Counting allocator (shared with tests/alloc_free.rs via benchkit): proves
// the fused path is allocation-free in steady state
// (`compress_into_allocs_per_call` in the JSON report).
#[global_allocator]
static GLOBAL: CountingAllocator = CountingAllocator;

fn gradient(d: usize, seed: u64) -> Vec<f32> {
    skewed_gradient(d, seed, 0.0)
}

fn main() {
    let b = Bencher::default();
    let mut report = JsonReport::new();

    section("greedy probability computation (Algorithm 3, 2 iters)");
    for d in [2048usize, 16_384, 262_144, 1 << 21] {
        let g = gradient(d, 1);
        let mut p = Vec::new();
        let s = b.bench(&format!("greedy_probs d={d}"), Some(d as u64), || {
            black_box(greedy_probs(black_box(&g), 0.05, 2, &mut p));
        });
        report.push(&s);
    }

    section("closed-form: full sort (reference) vs selection (hot path)");
    let mut speedup_262144 = 0.0f64;
    for d in [2048usize, 16_384, 262_144] {
        let g = gradient(d, 2);
        let mut p = Vec::new();
        let sorted = b.bench(&format!("closed_form_sorted d={d}"), Some(d as u64), || {
            black_box(closed_form_probs_sorted(black_box(&g), 0.5, &mut p));
        });
        let mut scratch = SelectScratch::default();
        let select = b.bench(&format!("closed_form_select d={d}"), Some(d as u64), || {
            black_box(closed_form_probs_with(
                black_box(&g),
                0.5,
                &mut p,
                &mut scratch,
            ));
        });
        let speedup = sorted.mean.as_secs_f64() / select.mean.as_secs_f64().max(1e-12);
        println!("    -> selection speedup at d={d}: {speedup:.2}x");
        report.push(&sorted);
        report.push(&select);
        report.push_metric(&format!("closed_form_select_speedup_d{d}"), speedup);
        if d == 262_144 {
            speedup_262144 = speedup;
        }
    }

    section("Bernoulli sampling + rescale (legacy allocating path)");
    for d in [2048usize, 262_144] {
        let g = gradient(d, 3);
        let mut p = Vec::new();
        let pv = greedy_probs(&g, 0.05, 2, &mut p);
        let mut rand = RandArray::from_seed(4, 1 << 22);
        let s = b.bench(&format!("sample_sparse d={d}"), Some(d as u64), || {
            black_box(sample_sparse(black_box(&g), &p, pv.inv_lambda, &mut rand));
        });
        report.push(&s);
    }

    section("fused engine compress_into (probs + sample + encode, reused buffers)");
    for d in [2048usize, 262_144, 1 << 21] {
        let g = gradient(d, 4);
        let mut rand = RandArray::from_seed(5, 1 << 22);
        let mut engine = CompressEngine::greedy(0.05, 2).with_sharding(1 << 14, usize::MAX, 1);
        engine.reserve(d);
        let mut out = SparseGrad::empty(d);
        let mut wire = Vec::new();
        let s = b.bench(&format!("engine_seq d={d}"), Some(d as u64), || {
            black_box(engine.compress_into(black_box(&g), &mut rand, &mut out, &mut wire));
        });
        report.push(&s);

        // Steady-state allocation count on the sequential path.
        engine.compress_into(&g, &mut rand, &mut out, &mut wire); // warm
        let before = allocation_count();
        let calls = 50;
        for _ in 0..calls {
            black_box(engine.compress_into(black_box(&g), &mut rand, &mut out, &mut wire));
        }
        let per_call = (allocation_count() - before) as f64 / calls as f64;
        println!("    -> engine_seq d={d}: {per_call:.2} allocations/call (steady state)");
        report.push_metric(&format!("compress_into_allocs_per_call_d{d}"), per_call);

        if d >= 1 << 16 {
            let mut par_engine = CompressEngine::greedy(0.05, 2).with_sharding(1 << 14, 1 << 16, 8);
            par_engine.reserve(d);
            let mut par_rand = RandArray::from_seed(5, 1 << 22);
            let s = b.bench(&format!("engine_sharded d={d}"), Some(d as u64), || {
                black_box(par_engine.compress_into(
                    black_box(&g),
                    &mut par_rand,
                    &mut out,
                    &mut wire,
                ));
            });
            report.push(&s);
        }
    }

    section("full compress step per method (d = 262144, rho = 0.05)");
    let d = 262_144;
    let g = gradient(d, 5);
    let mut rand = RandArray::from_seed(6, 1 << 22);
    for &m in Method::all() {
        let mut c = gsparse::api::MethodSpec::from_parts(m, 0.05, 0.5, 4).build();
        let mut out = sparsify::Compressed::Sparse(SparseGrad::empty(d));
        let s = b.bench(&format!("compress {m}"), Some(d as u64), || {
            black_box(c.compress_into(black_box(&g), &mut rand, &mut out));
        });
        report.push(&s);
    }

    let out_path =
        std::env::var("GSPARSE_BENCH_OUT").unwrap_or_else(|_| "BENCH_sparsify.json".to_string());
    report.push_metric("closed_form_select_speedup_d262144_gate", speedup_262144);
    match report.write(&out_path) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}
