//! Sparse all-reduce microbench at the paper's communication scale:
//! d = 2^20 coordinates at rho = 0.01, M ∈ {4, 16} ranks. Runs the real
//! budgeted ring collective over in-process links and a star hub exchange
//! over the same transport, reporting **measured** per-node hop bytes and
//! end-to-end bytes next to the α-β model's round times for both
//! topologies. Writes `BENCH_allreduce.json` (override with
//! `GSPARSE_BENCH_OUT`).

use gsparse::benchkit::{section, JsonReport};
use gsparse::coding::{self, WireCodec};
use gsparse::collective::{self, RingReducer};
use gsparse::comm::{merge, NetworkModel, Topology};
use gsparse::rngkit::Xoshiro256pp;
use gsparse::sparsify::SparseGrad;
use gsparse::transport::{accept_n_hello, Hello, InProcTransport, LinkCounters, Transport};
use std::time::Instant;

const D: usize = 1 << 20;
const RHO: f32 = 0.01;

/// ~`k`-entry sparse message with ascending indices — the shape a rho-sparse
/// compressor emits at this scale.
fn sparse_input(d: usize, k: usize, seed: u64) -> SparseGrad {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut sg = SparseGrad::empty(d);
    let stride = (d / k.max(1)).max(1) as u64;
    let mut idx = rng.next_below(stride) as usize;
    while idx < d && sg.exact.len() < k {
        sg.exact
            .push((idx as u32, (rng.next_gaussian() as f32).max(0.01)));
        idx += 1 + rng.next_below(2 * stride) as usize;
    }
    sg
}

/// Budgeted ring all-reduce over real in-process links. Returns (per-node
/// right-link tx bytes, encoded reduced-sum length, wall seconds).
fn run_ring(inputs: &[SparseGrad], m: usize) -> (Vec<u64>, usize, f64) {
    let transport = InProcTransport::new();
    let binds: Vec<String> = (0..m).map(|r| format!("ring-{m}-{r}")).collect();
    let peers = collective::form_ring_local(&transport, m, WireCodec::Raw, &binds)
        .expect("bench ring");
    let tx: Vec<LinkCounters> = peers.iter().map(|p| p.right_counters()).collect();
    let budget = Some(collective::default_budget(RHO, D as u32, m));
    let t0 = Instant::now();
    let reduced_len = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(m);
        for (mut peer, input) in peers.into_iter().zip(inputs) {
            handles.push(scope.spawn(move || {
                let mut reducer = RingReducer::new(WireCodec::Raw, budget);
                let mut out = SparseGrad::empty(0);
                reducer.reduce(&mut peer, input, &mut out, None).expect("bench reduce");
                let mut bytes = Vec::new();
                coding::encode_with(&out, WireCodec::Raw, &mut bytes);
                bytes.len()
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("bench rank"))
            .max()
            .unwrap_or(0)
    });
    let wall_s = t0.elapsed().as_secs_f64();
    (tx.iter().map(|c| c.bytes_tx()).collect(), reduced_len, wall_s)
}

/// Star all-reduce over the same transport: every rank uploads its message
/// to a hub and downloads the merged sum. Returns (per-node link bytes,
/// per-rank upload lengths, merged encoding length).
fn run_star(inputs: &[SparseGrad], m: usize) -> (Vec<u64>, Vec<u64>, usize) {
    let transport = InProcTransport::new();
    let hub = format!("star-{m}-hub");
    let mut listener = transport.listen(&hub).expect("bench hub");
    let uploads: Vec<u64> = inputs
        .iter()
        .map(|sg| {
            let mut bytes = Vec::new();
            coding::encode_with(sg, WireCodec::Raw, &mut bytes);
            bytes.len() as u64
        })
        .collect();
    let (per_node, merged_len) = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(m);
        for (w, input) in inputs.iter().enumerate() {
            let (t, hub) = (&transport, &hub);
            handles.push(scope.spawn(move || {
                let mut conn = t
                    .connect(hub, &Hello::with_codec(w as u32, WireCodec::Raw))
                    .expect("bench connect");
                let mut bytes = Vec::new();
                coding::encode_with(input, WireCodec::Raw, &mut bytes);
                conn.send(&bytes).expect("bench upload");
                let mut rx = Vec::new();
                conn.recv(&mut rx).expect("bench download");
                conn.counters().bytes_total()
            }));
        }
        let accepted = accept_n_hello(listener.as_mut(), m, WireCodec::Raw).expect("bench accept");
        let mut conns: Vec<_> = accepted.into_iter().map(|(c, _)| c).collect();
        let mut sum = SparseGrad::empty(D);
        let mut incoming = SparseGrad::empty(0);
        let mut merged = SparseGrad::empty(0);
        let mut rx = Vec::new();
        for conn in conns.iter_mut() {
            conn.recv(&mut rx).expect("bench hub recv");
            coding::decode_into(&rx, &mut incoming).expect("bench decode");
            merge::merge_sum(&sum, &incoming, &mut merged);
            std::mem::swap(&mut sum, &mut merged);
        }
        let mut down = Vec::new();
        coding::encode_with(&sum, WireCodec::Raw, &mut down);
        for conn in conns.iter_mut() {
            conn.send(&down).expect("bench hub send");
        }
        let per_node: Vec<u64> = handles
            .into_iter()
            .map(|h| h.join().expect("bench rank"))
            .collect();
        (per_node, down.len())
    });
    (per_node, uploads, merged_len)
}

fn bench_scale(report: &mut JsonReport, m: usize) {
    let k = (RHO * D as f32) as usize;
    let inputs: Vec<SparseGrad> = (0..m)
        .map(|w| sparse_input(D, k, 0xA11D ^ w as u64))
        .collect();

    let (ring_tx, ring_e2e, wall_s) = run_ring(&inputs, m);
    let ring_max = ring_tx.iter().copied().max().unwrap_or(0);
    let (star_per_node, uploads, merged_len) = run_star(&inputs, m);
    let star_min = star_per_node.iter().copied().min().unwrap_or(0);

    // α-β model of the same round under both topologies: uploads are the
    // measured per-rank message encodings, the broadcast is the merged sum.
    let mut net = NetworkModel::commodity_1g();
    net.topology = Topology::Star;
    let model_star_s = net.round_time_s(&uploads, merged_len as u64);
    net.topology = Topology::Ring;
    let model_ring_s = net.round_time_s(&uploads, merged_len as u64);

    section(&format!("M = {m}, d = 2^20, rho = {RHO}"));
    println!(
        "    ring: per-node hop tx {ring_max} B (e2e {ring_e2e} B, {:.1} ms wall)\n\
         \x20   star: per-node {star_min} B (merged download {merged_len} B)\n\
         \x20   model round: star {:.2} ms, ring {:.2} ms",
        wall_s * 1e3,
        model_star_s * 1e3,
        model_ring_s * 1e3,
    );
    assert!(
        ring_max < star_min,
        "M={m}: ring per-node bytes must beat star's"
    );

    report.push_metric(&format!("m{m}_ring_hop_bytes_per_node_max"), ring_max as f64);
    report.push_metric(
        &format!("m{m}_ring_hop_bytes_total"),
        ring_tx.iter().sum::<u64>() as f64,
    );
    report.push_metric(&format!("m{m}_ring_e2e_bytes"), ring_e2e as f64);
    report.push_metric(&format!("m{m}_ring_wall_s"), wall_s);
    report.push_metric(&format!("m{m}_star_bytes_per_node_min"), star_min as f64);
    report.push_metric(
        &format!("m{m}_star_broadcast_bytes"),
        merged_len as f64,
    );
    report.push_metric(
        &format!("m{m}_ring_vs_star_per_node_x"),
        star_min as f64 / ring_max.max(1) as f64,
    );
    report.push_metric(&format!("m{m}_model_star_round_s"), model_star_s);
    report.push_metric(&format!("m{m}_model_ring_round_s"), model_ring_s);
}

fn main() {
    let mut report = JsonReport::new();
    for m in [4usize, 16] {
        bench_scale(&mut report, m);
    }
    let out_path = std::env::var("GSPARSE_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_allreduce.json".to_string());
    match report.write(&out_path) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}
