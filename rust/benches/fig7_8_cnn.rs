//! Bench target regenerating **Figures 7 and 8** (CNNs on the CIFAR-like
//! dataset, Adam + per-layer sparsification, loss vs epochs and vs comm
//! cost). Requires `make artifacts` (Fig 7) / `make artifacts-full`
//! (Fig 8's 48/64-channel variants).

fn main() {
    let quick = std::env::var("GSPARSE_PAPER").is_err();
    if let Err(e) = gsparse::figures::fig7(quick) {
        eprintln!("fig7 failed (did you run `make artifacts`?): {e:#}");
        std::process::exit(1);
    }
    if let Err(e) = gsparse::figures::fig8(quick) {
        eprintln!("fig8: {e:#}");
    }
}
