//! Bench target regenerating **Figures 7 and 8** (CNNs on the CIFAR-like
//! dataset, Adam + per-layer sparsification, loss vs epochs and vs comm
//! cost). Requires `make artifacts` (Fig 7) / `make artifacts-full`
//! (Fig 8's 48/64-channel variants).

fn main() {
    let quick = std::env::var("GSPARSE_PAPER").is_err();
    let batch = std::env::var("GSPARSE_BATCH_LAYERS").is_ok();
    if let Err(e) = gsparse::figures::fig7(quick, batch) {
        eprintln!("fig7 failed (did you run `make artifacts`?): {e:#}");
        std::process::exit(1);
    }
    if let Err(e) = gsparse::figures::fig8(quick, batch) {
        eprintln!("fig8: {e:#}");
    }
}
