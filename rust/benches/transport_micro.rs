//! Transport microbench: frame-codec throughput plus whole-cluster runs on
//! both backends, reporting the **measured** byte column (actual framed
//! bytes on the link) next to the idealized ledger. Writes
//! `BENCH_transport.json` (override with `GSPARSE_BENCH_OUT`); CI uploads
//! it alongside `BENCH_sparsify.json` to track the transport's overhead
//! trajectory.

use gsparse::benchkit::{black_box, section, Bencher, JsonReport};
use gsparse::coding::{BatchStreamEncoder, WireCodec};
use gsparse::coordinator::dist::{self, RunPlan};
use gsparse::rngkit::RandArray;
use gsparse::sparsify::{greedy_probs, sample_sparse, BatchCompressEngine, SparseGrad};
use gsparse::transport::frame::{self, GradHeader, MsgView};
use gsparse::transport::{
    Hello, InProcTransport, Listener, TcpTransport, Transport, FRAME_OVERHEAD,
};
use std::time::{Duration, Instant};

fn bench_frame_codec(report: &mut JsonReport) {
    section("frame codec (grad message, d = 2048, rho = 0.1)");
    let d = 2048;
    let g = gsparse::benchkit::skewed_gradient(d, 11, 0.1);
    let mut p = Vec::new();
    let pv = greedy_probs(&g, 0.1, 2, &mut p);
    let mut rand = RandArray::from_seed(12, 1 << 16);
    let sg = sample_sparse(&g, &p, pv.inv_lambda, &mut rand);
    let mut wire = Vec::new();
    gsparse::coding::encode(&sg, &mut wire);
    let header = GradHeader {
        based_on: 1,
        g_norm_sq: 2.0,
        q_norm_sq: 2.5,
        expected_nnz: pv.expected_nnz,
        ideal_bits: 12345,
        kind: 0,
    };
    let bench = Bencher::default();
    let mut frame_buf = Vec::new();
    let s = bench.bench("frame encode_grad", Some(wire.len() as u64), || {
        frame::encode_grad(&mut frame_buf, &header, black_box(&wire));
    });
    report.push(&s);
    let s = bench.bench("frame decode(grad)", Some(frame_buf.len() as u64), || {
        match frame::decode(black_box(&frame_buf)).unwrap() {
            MsgView::Grad { payload, .. } => {
                black_box(payload.len());
            }
            _ => unreachable!(),
        }
    });
    report.push(&s);
    report.push_metric("frame_overhead_bytes", FRAME_OVERHEAD as f64);
}

fn bench_cluster(report: &mut JsonReport, backend: &str) {
    let cfg = RunPlan {
        workers: 2,
        rounds: 150,
        n: 512,
        d: 1024,
        batch: 8,
        seed: 9,
        reg: 1.0 / (10.0 * 512.0),
        ..Default::default()
    };
    let t0 = Instant::now();
    let rep = match backend {
        "inproc" => dist::run_threads(InProcTransport::new(), "bench", &cfg),
        "tcp" => dist::run_threads(TcpTransport::new(), "127.0.0.1:0", &cfg),
        other => panic!("unknown backend {other}"),
    }
    .expect("cluster run");
    let wall_s = t0.elapsed().as_secs_f64();
    let pushes = (cfg.rounds * cfg.workers) as f64;
    let ledger = &rep.curve.ledger;
    let overhead = ledger.measured_bytes as f64 / ledger.wire_bytes.max(1) as f64;
    println!(
        "{backend:>7}: {pushes} pushes in {:.1} ms  wire {} B  measured {} B \
         ({overhead:.3}x incl. weights+framing)  final loss {:.6}",
        wall_s * 1e3,
        ledger.wire_bytes,
        ledger.measured_bytes,
        rep.final_loss,
    );
    report.push_metric(&format!("{backend}_wall_s"), wall_s);
    report.push_metric(&format!("{backend}_pushes_per_s"), pushes / wall_s);
    report.push_metric(&format!("{backend}_wire_bytes"), ledger.wire_bytes as f64);
    report.push_metric(
        &format!("{backend}_measured_bytes"),
        ledger.measured_bytes as f64,
    );
    report.push_metric(
        &format!("{backend}_measured_bytes_per_push"),
        ledger.measured_bytes as f64 / pushes,
    );
    report.push_metric(&format!("{backend}_framing_overhead_x"), overhead);
    report.push_metric(&format!("{backend}_sim_net_s"), rep.sim_time_s);
    report.push_metric(
        &format!("{backend}_grad_digest_low32"),
        (rep.grad_digest & 0xFFFF_FFFF) as f64,
    );
}

// ---- pipelined compression <-> network overlap ------------------------
//
// The ISSUE-6 acceptance workload: d = 2^20 coordinates (16 layers of
// 65536) at rho = 0.01 over loopback TCP, against a receiver that "drains
// the wire" at a paced rate calibrated to the measured compression time —
// so compute and wire are comparably expensive, the regime where overlap
// matters. Depth 1 runs the reference encode-then-send path; depth >= 2
// keeps frames in flight via the streaming WireBatch encoder + vectored
// gather writes. The receiver digests every frame, proving the two paths
// put bitwise-identical bytes on the wire.

const PIPE_LAYERS: usize = 16;
const PIPE_LAYER_D: usize = 1 << 16; // 16 x 65536 = 2^20 coordinates
const PIPE_ROUNDS: usize = 8;
const PIPE_DEPTH: usize = 2;
const PIPE_RHO: f32 = 0.01;

fn fnv1a(mut hash: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        hash ^= b as u64;
        hash = hash.wrapping_mul(0x0000_0100_0000_01B3);
    }
    hash
}

fn pipe_rand() -> RandArray {
    RandArray::from_seed(2020, 1 << 21)
}

fn pipe_header(round: usize) -> GradHeader {
    GradHeader {
        based_on: round as u64,
        g_norm_sq: 0.0,
        q_norm_sq: 0.0,
        expected_nnz: 0.0,
        ideal_bits: 0,
        kind: 0,
    }
}

/// Paced ack receiver: recv `rounds` frames, FNV-digest each, hold each
/// for `pace` (the simulated wire drain), then ack with one byte.
fn spawn_receiver(
    mut listener: Box<dyn Listener>,
    rounds: usize,
    pace: Duration,
) -> std::thread::JoinHandle<u64> {
    std::thread::spawn(move || {
        let (mut conn, _hello) = listener.accept().expect("bench accept");
        let mut buf = Vec::new();
        let mut digest = 0xCBF2_9CE4_8422_2325u64; // FNV-1a offset basis
        for _ in 0..rounds {
            conn.recv(&mut buf).expect("bench frame");
            digest = fnv1a(digest, &buf);
            std::thread::sleep(pace);
            conn.send(b"k").expect("bench ack");
        }
        digest
    })
}

/// Average seconds per compress+encode round (the work that must finish
/// before the frame's bytes exist), measured with no network attached.
fn pipe_compress_round_s(refs: &[&[f32]], codec: WireCodec) -> f64 {
    let mut engine = BatchCompressEngine::greedy(PIPE_RHO, 2);
    let mut rand = pipe_rand();
    let mut outs: Vec<SparseGrad> = Vec::new();
    let mut pvs = Vec::new();
    let mut wire = Vec::new();
    // One warmup round grows every scratch buffer to steady state.
    engine.compress_batch_into(refs, codec, &mut rand, &mut outs, &mut wire, &mut pvs);
    let mut rand = pipe_rand();
    let t0 = Instant::now();
    for _ in 0..PIPE_ROUNDS {
        engine.compress_batch_into(refs, codec, &mut rand, &mut outs, &mut wire, &mut pvs);
        black_box(wire.len());
    }
    t0.elapsed().as_secs_f64() / PIPE_ROUNDS as f64
}

/// One pre-encoded `GRAD_BATCH` frame for the wire-only measurement.
fn pipe_one_frame(refs: &[&[f32]], codec: WireCodec) -> Vec<u8> {
    let mut engine = BatchCompressEngine::greedy(PIPE_RHO, 2);
    let mut rand = pipe_rand();
    let mut outs: Vec<SparseGrad> = Vec::new();
    let mut pvs = Vec::new();
    let mut wire = Vec::new();
    engine.compress_batch_into(refs, codec, &mut rand, &mut outs, &mut wire, &mut pvs);
    let mut frame_buf = Vec::new();
    frame::encode_grad_batch(&mut frame_buf, &pipe_header(0), &wire);
    frame_buf
}

/// Average seconds per round of pure wire work: ship the same pre-encoded
/// frame `PIPE_ROUNDS` times through the paced receiver, one ack at a time.
fn pipe_wire_round_s(frame_bytes: &[u8], codec: WireCodec, pace: Duration) -> f64 {
    let transport = TcpTransport::new();
    let listener = transport.listen("127.0.0.1:0").expect("bench listen");
    let addr = listener.local_addr();
    let rx = spawn_receiver(listener, PIPE_ROUNDS, pace);
    let mut conn = transport
        .connect(&addr, &Hello::with_codec(0, codec))
        .expect("bench connect");
    let mut ack = Vec::new();
    let t0 = Instant::now();
    for _ in 0..PIPE_ROUNDS {
        conn.send(frame_bytes).expect("bench send");
        conn.recv(&mut ack).expect("bench ack");
    }
    let per_round = t0.elapsed().as_secs_f64() / PIPE_ROUNDS as f64;
    rx.join().expect("receiver thread");
    per_round
}

/// A full compress-and-ship run at in-flight window `depth` (1 = the
/// sequential reference path). Returns (seconds per round, the receiver's
/// frame digest, the link's vectored-frame count).
fn pipe_run(
    refs: &[&[f32]],
    codec: WireCodec,
    pace: Duration,
    depth: usize,
) -> (f64, u64, u64) {
    let transport = TcpTransport::new();
    let listener = transport.listen("127.0.0.1:0").expect("bench listen");
    let addr = listener.local_addr();
    let rx = spawn_receiver(listener, PIPE_ROUNDS, pace);
    let mut conn = transport
        .connect(&addr, &Hello::with_codec(0, codec))
        .expect("bench connect");

    let mut engine = BatchCompressEngine::greedy(PIPE_RHO, 2);
    let mut rand = pipe_rand();
    let mut outs: Vec<SparseGrad> = (0..refs.len()).map(|_| SparseGrad::empty(0)).collect();
    let mut pvs = Vec::new();
    let mut wire = Vec::new();
    let mut frame_buf = Vec::new();
    let mut seg_bufs: Vec<Vec<u8>> = vec![Vec::new(); refs.len()];
    let mut ack = Vec::new();
    let mut outstanding = 0usize;

    let t0 = Instant::now();
    for round in 0..PIPE_ROUNDS {
        let header = pipe_header(round);
        if depth >= 2 {
            // Streaming path: solve + sample, then encode each layer into
            // its own segment and gather-write the frame — no contiguous
            // WireBatch assembly, no frame-buffer copy.
            {
                let mut slots: Vec<&mut SparseGrad> = outs.iter_mut().collect();
                engine.compress_batch_sparse_into(refs, &mut rand, &mut slots, &mut pvs);
            }
            let sgs: Vec<&SparseGrad> = outs.iter().collect();
            let mut enc = BatchStreamEncoder::plan(&sgs, codec);
            for (sg, seg) in sgs.iter().zip(seg_bufs.iter_mut()) {
                enc.encode_next(sg, seg);
            }
            frame::encode_grad_batch_prefix(&mut frame_buf, &header);
            let mut segments: Vec<&[u8]> = Vec::with_capacity(2 + seg_bufs.len());
            segments.push(&frame_buf);
            segments.push(enc.header());
            segments.extend(seg_bufs.iter().map(|s| s.as_slice()));
            conn.send_vectored(&segments).expect("bench send");
        } else {
            engine.compress_batch_into(refs, codec, &mut rand, &mut outs, &mut wire, &mut pvs);
            frame::encode_grad_batch(&mut frame_buf, &header, &wire);
            conn.send(&frame_buf).expect("bench send");
        }
        outstanding += 1;
        if outstanding >= depth {
            conn.recv(&mut ack).expect("bench ack");
            outstanding -= 1;
        }
    }
    while outstanding > 0 {
        conn.recv(&mut ack).expect("bench ack");
        outstanding -= 1;
    }
    let per_round = t0.elapsed().as_secs_f64() / PIPE_ROUNDS as f64;
    let digest = rx.join().expect("receiver thread");
    (per_round, digest, conn.counters().frames_vectored())
}

fn bench_pipeline(report: &mut JsonReport) {
    section(&format!(
        "pipelined rounds: d = 2^20 ({PIPE_LAYERS} x {PIPE_LAYER_D}), rho = {PIPE_RHO}, \
         tcp, depth {PIPE_DEPTH}"
    ));
    let layers: Vec<Vec<f32>> = (0..PIPE_LAYERS)
        .map(|l| gsparse::benchkit::skewed_gradient(PIPE_LAYER_D, 100 + l as u64, 0.3))
        .collect();
    let refs: Vec<&[f32]> = layers.iter().map(|g| g.as_slice()).collect();
    for codec in [WireCodec::Raw, WireCodec::Entropy] {
        let cname = match codec {
            WireCodec::Raw => "raw",
            WireCodec::Entropy => "entropy",
        };
        let compress_s = pipe_compress_round_s(&refs, codec);
        // Pace the receiver so the simulated wire drain is comparable to
        // (but cheaper than) compression — the max(compress, wire) regime
        // the overlap targets. Clamped away from scheduler granularity.
        let pace = Duration::from_secs_f64((0.75 * compress_s).clamp(0.0005, 0.05));
        let one_frame = pipe_one_frame(&refs, codec);
        let wire_s = pipe_wire_round_s(&one_frame, codec, pace);
        let (seq_s, seq_digest, _) = pipe_run(&refs, codec, pace, 1);
        let (pipe_s, pipe_digest, vectored) = pipe_run(&refs, codec, pace, PIPE_DEPTH);
        assert_eq!(
            seq_digest, pipe_digest,
            "{cname}: pipelined frames must be bitwise identical to sequential"
        );
        assert!(
            vectored >= PIPE_ROUNDS as u64,
            "{cname}: every pipelined frame should take the vectored zero-copy path"
        );
        let overlap_ratio = pipe_s / compress_s.max(wire_s);
        let vs_sum_ratio = pipe_s / (compress_s + wire_s);
        println!(
            "{cname:>7}: compress {:.2} ms  wire {:.2} ms  sequential {:.2} ms  \
             pipelined {:.2} ms  ({overlap_ratio:.2}x max, {vs_sum_ratio:.2}x sum)  \
             frame {} B  vectored {vectored}",
            compress_s * 1e3,
            wire_s * 1e3,
            seq_s * 1e3,
            pipe_s * 1e3,
            one_frame.len(),
        );
        report.push_metric(&format!("pipeline_{cname}_compress_round_s"), compress_s);
        report.push_metric(&format!("pipeline_{cname}_wire_round_s"), wire_s);
        report.push_metric(&format!("pipeline_{cname}_sequential_round_s"), seq_s);
        report.push_metric(&format!("pipeline_{cname}_pipelined_round_s"), pipe_s);
        report.push_metric(&format!("pipeline_{cname}_overlap_ratio"), overlap_ratio);
        report.push_metric(&format!("pipeline_{cname}_vs_sum_ratio"), vs_sum_ratio);
        report.push_metric(
            &format!("pipeline_{cname}_digest_match"),
            f64::from(u8::from(seq_digest == pipe_digest)),
        );
        report.push_metric(
            &format!("pipeline_{cname}_frames_vectored"),
            vectored as f64,
        );
        report.push_metric(
            &format!("pipeline_{cname}_frame_bytes"),
            one_frame.len() as f64,
        );
    }
}

fn main() {
    let mut report = JsonReport::new();
    bench_frame_codec(&mut report);
    section("distributed parameter server, 2 workers x 150 rounds (d = 1024)");
    bench_cluster(&mut report, "inproc");
    bench_cluster(&mut report, "tcp");
    bench_pipeline(&mut report);
    let out_path = std::env::var("GSPARSE_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_transport.json".to_string());
    match report.write(&out_path) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}
