//! Transport microbench: frame-codec throughput plus whole-cluster runs on
//! both backends, reporting the **measured** byte column (actual framed
//! bytes on the link) next to the idealized ledger. Writes
//! `BENCH_transport.json` (override with `GSPARSE_BENCH_OUT`); CI uploads
//! it alongside `BENCH_sparsify.json` to track the transport's overhead
//! trajectory.

use gsparse::benchkit::{black_box, section, Bencher, JsonReport};
use gsparse::coordinator::dist::{self, RunPlan};
use gsparse::rngkit::RandArray;
use gsparse::sparsify::{greedy_probs, sample_sparse};
use gsparse::transport::frame::{self, GradHeader, MsgView};
use gsparse::transport::{InProcTransport, TcpTransport, FRAME_OVERHEAD};
use std::time::Instant;

fn bench_frame_codec(report: &mut JsonReport) {
    section("frame codec (grad message, d = 2048, rho = 0.1)");
    let d = 2048;
    let g = gsparse::benchkit::skewed_gradient(d, 11, 0.1);
    let mut p = Vec::new();
    let pv = greedy_probs(&g, 0.1, 2, &mut p);
    let mut rand = RandArray::from_seed(12, 1 << 16);
    let sg = sample_sparse(&g, &p, pv.inv_lambda, &mut rand);
    let mut wire = Vec::new();
    gsparse::coding::encode(&sg, &mut wire);
    let header = GradHeader {
        based_on: 1,
        g_norm_sq: 2.0,
        q_norm_sq: 2.5,
        expected_nnz: pv.expected_nnz,
        ideal_bits: 12345,
        kind: 0,
    };
    let bench = Bencher::default();
    let mut frame_buf = Vec::new();
    let s = bench.bench("frame encode_grad", Some(wire.len() as u64), || {
        frame::encode_grad(&mut frame_buf, &header, black_box(&wire));
    });
    report.push(&s);
    let s = bench.bench("frame decode(grad)", Some(frame_buf.len() as u64), || {
        match frame::decode(black_box(&frame_buf)).unwrap() {
            MsgView::Grad { payload, .. } => {
                black_box(payload.len());
            }
            _ => unreachable!(),
        }
    });
    report.push(&s);
    report.push_metric("frame_overhead_bytes", FRAME_OVERHEAD as f64);
}

fn bench_cluster(report: &mut JsonReport, backend: &str) {
    let cfg = RunPlan {
        workers: 2,
        rounds: 150,
        n: 512,
        d: 1024,
        batch: 8,
        seed: 9,
        reg: 1.0 / (10.0 * 512.0),
        ..Default::default()
    };
    let t0 = Instant::now();
    let rep = match backend {
        "inproc" => dist::run_threads(InProcTransport::new(), "bench", &cfg),
        "tcp" => dist::run_threads(TcpTransport::new(), "127.0.0.1:0", &cfg),
        other => panic!("unknown backend {other}"),
    }
    .expect("cluster run");
    let wall_s = t0.elapsed().as_secs_f64();
    let pushes = (cfg.rounds * cfg.workers) as f64;
    let ledger = &rep.curve.ledger;
    let overhead = ledger.measured_bytes as f64 / ledger.wire_bytes.max(1) as f64;
    println!(
        "{backend:>7}: {pushes} pushes in {:.1} ms  wire {} B  measured {} B \
         ({overhead:.3}x incl. weights+framing)  final loss {:.6}",
        wall_s * 1e3,
        ledger.wire_bytes,
        ledger.measured_bytes,
        rep.final_loss,
    );
    report.push_metric(&format!("{backend}_wall_s"), wall_s);
    report.push_metric(&format!("{backend}_pushes_per_s"), pushes / wall_s);
    report.push_metric(&format!("{backend}_wire_bytes"), ledger.wire_bytes as f64);
    report.push_metric(
        &format!("{backend}_measured_bytes"),
        ledger.measured_bytes as f64,
    );
    report.push_metric(
        &format!("{backend}_measured_bytes_per_push"),
        ledger.measured_bytes as f64 / pushes,
    );
    report.push_metric(&format!("{backend}_framing_overhead_x"), overhead);
    report.push_metric(&format!("{backend}_sim_net_s"), rep.sim_time_s);
    report.push_metric(
        &format!("{backend}_grad_digest_low32"),
        (rep.grad_digest & 0xFFFF_FFFF) as f64,
    );
}

fn main() {
    let mut report = JsonReport::new();
    bench_frame_codec(&mut report);
    section("distributed parameter server, 2 workers x 150 rounds (d = 1024)");
    bench_cluster(&mut report, "inproc");
    bench_cluster(&mut report, "tcp");
    let out_path = std::env::var("GSPARSE_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_transport.json".to_string());
    match report.write(&out_path) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}
