//! Bench target regenerating **Figure 9** (asynchronous multi-thread SVM,
//! Algorithm 4, loss vs wall-clock; GSpar vs dense across thread counts and
//! regularization strengths), plus the Lock/Atomic/Wild scheme ablation.

use gsparse::benchkit::section;
use gsparse::config::{AsyncSvmConfig, Method, UpdateScheme};
use gsparse::coordinator::AsyncSvmEngine;
use gsparse::data::gen_svm;

fn main() {
    let quick = std::env::var("GSPARSE_PAPER").is_err();
    gsparse::figures::fig9(quick);

    section("ablation: update scheme (Lock vs Atomic vs Wild) at 8 threads");
    let ds = gen_svm(8192, 256, 0.01, 0.9, 77);
    println!(
        "{:<22} {:>9} {:>12} {:>12}",
        "config", "wall_ms", "final_loss", "conflicts"
    );
    for scheme in [UpdateScheme::Lock, UpdateScheme::Atomic, UpdateScheme::Wild] {
        for method in [Method::Dense, Method::GSpar] {
            let cfg = AsyncSvmConfig {
                n: 8192,
                d: 256,
                reg: 0.1,
                rho: 0.05,
                threads: 8,
                lr: 0.05,
                method,
                seed: 77,
                total_steps: 30_000,
                scheme,
                ..Default::default()
            };
            let r = AsyncSvmEngine::new(cfg).run(&ds);
            println!(
                "{:<22} {:>9.1} {:>12.5} {:>12}",
                format!(
                    "{}+{scheme}",
                    if method == Method::Dense { "dense" } else { "GSpar" }
                ),
                r.wall_ms,
                r.final_loss,
                r.conflicts
            );
        }
    }
}
