//! Bench target regenerating **Figures 1 and 2** (synchronous SGD on
//! synthetic logistic regression, GSpar vs UniSp vs dense, both C₁
//! settings). Prints the same series/labels the paper plots and times one
//! representative cell end-to-end.
//!
//! Scale: quick by default; set GSPARSE_PAPER=1 for the paper's exact
//! N=1024 / d=2048 / 30 passes.

use gsparse::benchkit::{section, Bencher};
use gsparse::figures::{fig1, fig2, ConvexFigureScale};

fn main() {
    let paper = std::env::var("GSPARSE_PAPER").is_ok();
    let scale = if paper {
        ConvexFigureScale::paper()
    } else {
        ConvexFigureScale::quick()
    };
    fig1(&scale);
    fig2(&scale);

    section("end-to-end wall time of one Fig-1 cell");
    let b = Bencher::heavy();
    b.bench("fig1 cell (3 methods)", None, || {
        // One cell = the grid function with a single (reg, C2) pair; reuse
        // fig1's internals via the public Session train path.
        use gsparse::api::{MethodSpec, Session, SyncTask};
        use gsparse::config::Method;
        use gsparse::data::gen_logistic;
        use gsparse::model::LogisticModel;
        let (n, d, seed) = (256usize, 512usize, 42u64);
        let (c1, c2) = (0.6f32, 0.25f32);
        let ds = gen_logistic(n, d, c1, c2, seed);
        let model = LogisticModel::new(1.0 / (10.0 * n as f32));
        let task = SyncTask {
            epochs: 6,
            lr: 0.5,
            ..SyncTask::default()
        };
        for m in [Method::Dense, Method::GSpar, Method::UniSp] {
            let session = Session::builder()
                .method(MethodSpec::from_parts(m, 0.1, c2 * c1, 4))
                .workers(4)
                .seed(seed)
                .build();
            session.train_convex(&task, &ds, &model);
        }
    });
}
