//! Bench target regenerating **Figures 1 and 2** (synchronous SGD on
//! synthetic logistic regression, GSpar vs UniSp vs dense, both C₁
//! settings). Prints the same series/labels the paper plots and times one
//! representative cell end-to-end.
//!
//! Scale: quick by default; set GSPARSE_PAPER=1 for the paper's exact
//! N=1024 / d=2048 / 30 passes.

use gsparse::benchkit::{section, Bencher};
use gsparse::figures::{fig1, fig2, ConvexFigureScale};

fn main() {
    let paper = std::env::var("GSPARSE_PAPER").is_ok();
    let scale = if paper {
        ConvexFigureScale::paper()
    } else {
        ConvexFigureScale::quick()
    };
    fig1(&scale);
    fig2(&scale);

    section("end-to-end wall time of one Fig-1 cell");
    let b = Bencher::heavy();
    b.bench("fig1 cell (3 methods)", None, || {
        let s = ConvexFigureScale {
            n: 256,
            d: 512,
            epochs: 6,
            seed: 1,
        };
        // One cell = the grid function with a single (reg, C2) pair; reuse
        // fig1's internals via the public train path.
        let _ = s;
        use gsparse::config::{ConvexConfig, Method};
        use gsparse::coordinator::sync::{train_convex, TrainOptions};
        use gsparse::data::gen_logistic;
        use gsparse::model::LogisticModel;
        let cfg = ConvexConfig {
            n: 256,
            d: 512,
            epochs: 6,
            ..Default::default()
        };
        let ds = gen_logistic(cfg.n, cfg.d, cfg.c1, cfg.c2, cfg.seed);
        let model = LogisticModel::new(cfg.reg);
        for m in [Method::Dense, Method::GSpar, Method::UniSp] {
            let mut c = cfg.clone();
            c.method = m;
            train_convex(&c, &TrainOptions::default(), &ds, &model);
        }
    });
}
