//! A minimal Rust source scanner: blanks comments and string/char literals
//! while preserving byte offsets and line structure, and marks `#[cfg(test)]`
//! regions. No parser dependency — the lint rules only need token-level
//! facts (identifier occurrences, brace matching, attribute positions), and
//! the offline image has no registry to pull `syn` from anyway.
//!
//! The one genuinely ambiguous construct at this level is `'` — lifetime
//! versus char literal. The heuristic: `'\` always opens a char literal;
//! `'x'` (closing quote two bytes later) is a char literal; a `'` followed
//! by a non-ASCII scalar with a closing `'` within a few bytes is a char
//! literal; everything else is a lifetime/label and passes through.

/// One scanned source file.
pub struct SourceFile {
    /// Repo-relative path with forward slashes, e.g. `rust/src/lib.rs`.
    pub path: String,
    /// Original text.
    pub raw: String,
    /// Same byte length as `raw`, with comments and string/char-literal
    /// contents replaced by spaces (newlines kept, delimiters kept).
    pub code: String,
    /// `test_lines[i]` is true when 1-based line `i+1` is inside a
    /// `#[cfg(test)]` item or the file lives under a `tests/` directory.
    pub test_lines: Vec<bool>,
    /// Byte offset of the start of each line (index 0 = line 1).
    pub line_starts: Vec<usize>,
}

impl SourceFile {
    pub fn new(path: String, raw: String) -> Self {
        let code = strip(&raw);
        let line_starts = line_starts(&raw);
        let is_test_file = path.contains("/tests/") || path.starts_with("tests/");
        let test_lines = if is_test_file {
            vec![true; line_starts.len()]
        } else {
            test_regions(&code, &line_starts)
        };
        Self {
            path,
            raw,
            code,
            test_lines,
            line_starts,
        }
    }

    /// 1-based line number of a byte offset.
    pub fn line_of(&self, offset: usize) -> usize {
        match self.line_starts.binary_search(&offset) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }

    /// Whether the byte offset falls in a test region.
    pub fn is_test_at(&self, offset: usize) -> bool {
        self.test_lines
            .get(self.line_of(offset) - 1)
            .copied()
            .unwrap_or(false)
    }

    /// Raw text of a 1-based line (without the trailing newline).
    pub fn raw_line(&self, line: usize) -> &str {
        let start = self.line_starts[line - 1];
        let end = self
            .line_starts
            .get(line)
            .map(|&e| e.saturating_sub(1))
            .unwrap_or(self.raw.len());
        &self.raw[start..end.max(start)]
    }

    pub fn lines(&self) -> usize {
        self.line_starts.len()
    }
}

fn line_starts(s: &str) -> Vec<usize> {
    let mut v = vec![0usize];
    for (i, b) in s.bytes().enumerate() {
        if b == b'\n' && i + 1 < s.len() {
            v.push(i + 1);
        }
    }
    v
}

/// Blank comments and string/char literals, preserving byte offsets.
pub fn strip(src: &str) -> String {
    let b = src.as_bytes();
    let mut out: Vec<u8> = Vec::with_capacity(b.len());
    let mut i = 0usize;
    let push_blanked = |out: &mut Vec<u8>, c: u8| {
        out.push(if c == b'\n' { b'\n' } else { b' ' });
    };
    while i < b.len() {
        let c = b[i];
        // Line comment.
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            while i < b.len() && b[i] != b'\n' {
                out.push(b' ');
                i += 1;
            }
            continue;
        }
        // Block comment (nests).
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
            let mut depth = 1usize;
            out.extend_from_slice(b"  ");
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    depth += 1;
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    depth -= 1;
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else {
                    push_blanked(&mut out, b[i]);
                    i += 1;
                }
            }
            continue;
        }
        // Raw string r"..." / r#"..."# (optionally byte: br"...").
        if c == b'r' && i + 1 < b.len() && (b[i + 1] == b'"' || b[i + 1] == b'#') {
            let prev = if i == 0 { b' ' } else { b[i - 1] };
            let prev_prev = if i < 2 { b' ' } else { b[i - 2] };
            let ident = |x: u8| x.is_ascii_alphanumeric() || x == b'_';
            let ok_prefix = !ident(prev) || (prev == b'b' && !ident(prev_prev));
            if ok_prefix {
                let mut j = i + 1;
                let mut hashes = 0usize;
                while j < b.len() && b[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
                if j < b.len() && b[j] == b'"' {
                    out.push(b' '); // the `r`
                    for _ in 0..hashes {
                        out.push(b' ');
                    }
                    out.push(b'"');
                    j += 1;
                    while j < b.len() {
                        if b[j] == b'"' {
                            let mut k = j + 1;
                            let mut h = 0usize;
                            while k < b.len() && h < hashes && b[k] == b'#' {
                                h += 1;
                                k += 1;
                            }
                            if h == hashes {
                                out.push(b'"');
                                for _ in 0..hashes {
                                    out.push(b' ');
                                }
                                j = k;
                                break;
                            }
                        }
                        push_blanked(&mut out, b[j]);
                        j += 1;
                    }
                    i = j;
                    continue;
                }
            }
        }
        // Regular string.
        if c == b'"' {
            out.push(b'"');
            i += 1;
            while i < b.len() {
                if b[i] == b'\\' && i + 1 < b.len() {
                    out.extend_from_slice(b"  ");
                    i += 2;
                    continue;
                }
                if b[i] == b'"' {
                    out.push(b'"');
                    i += 1;
                    break;
                }
                push_blanked(&mut out, b[i]);
                i += 1;
            }
            continue;
        }
        // Char literal or lifetime.
        if c == b'\'' {
            if i + 1 < b.len() && b[i + 1] == b'\\' {
                // Escaped char literal: scan to the closing quote.
                out.push(b'\'');
                i += 1;
                while i < b.len() {
                    if b[i] == b'\\' && i + 1 < b.len() {
                        out.extend_from_slice(b"  ");
                        i += 2;
                        continue;
                    }
                    if b[i] == b'\'' {
                        out.push(b'\'');
                        i += 1;
                        break;
                    }
                    out.push(b' ');
                    i += 1;
                }
                continue;
            }
            if i + 2 < b.len() && b[i + 2] == b'\'' && b[i + 1] != b'\'' && b[i + 1] < 0x80 {
                // Simple one-byte char literal 'x'.
                out.extend_from_slice(b"' '");
                i += 3;
                continue;
            }
            if i + 1 < b.len() && b[i + 1] >= 0x80 {
                // Multi-byte scalar char literal: closing quote within 5 bytes.
                let mut close = None;
                for k in 2..=5usize {
                    if i + k < b.len() && b[i + k] == b'\'' {
                        close = Some(k);
                        break;
                    }
                }
                if let Some(k) = close {
                    out.push(b'\'');
                    for _ in 0..k - 1 {
                        out.push(b' ');
                    }
                    out.push(b'\'');
                    i += k + 1;
                    continue;
                }
            }
            // Lifetime / label: pass the quote through.
            out.push(b'\'');
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    // All substituted bytes are ASCII and original multi-byte sequences are
    // either copied whole or fully blanked, so this is valid UTF-8.
    String::from_utf8(out).expect("stripped source is valid UTF-8")
}

/// Mark lines covered by `#[cfg(test)]` items (attribute through the end of
/// the item's brace block, or through `;` for block-less items).
fn test_regions(code: &str, line_starts: &[usize]) -> Vec<bool> {
    let mut flags = vec![false; line_starts.len()];
    let bytes = code.as_bytes();
    let mut search = 0usize;
    while let Some(rel) = code[search..].find("#[cfg(test)]") {
        let attr_start = search + rel;
        let attr_end = attr_start + "#[cfg(test)]".len();
        search = attr_end;
        let Some(item_end) = item_end_after(bytes, attr_end) else {
            // Unterminated item: mark through end of file.
            mark_lines(&mut flags, line_starts, attr_start, code.len());
            break;
        };
        mark_lines(&mut flags, line_starts, attr_start, item_end);
        search = item_end;
    }
    flags
}

/// Given stripped source and an offset just past an attribute, return the
/// offset one past the end of the item the attribute is attached to: the
/// matching `}` of the first brace block, or the first `;` when it precedes
/// any `{` (use declarations, tuple structs, extern fns).
pub fn item_end_after(bytes: &[u8], mut i: usize) -> Option<usize> {
    // Skip whitespace and any further attributes before the item keyword.
    loop {
        while i < bytes.len() && (bytes[i] as char).is_whitespace() {
            i += 1;
        }
        if i + 1 < bytes.len() && bytes[i] == b'#' && bytes[i + 1] == b'[' {
            // Skip a (possibly bracket-nested) attribute.
            let mut depth = 0usize;
            while i < bytes.len() {
                match bytes[i] {
                    b'[' => depth += 1,
                    b']' => {
                        depth -= 1;
                        if depth == 0 {
                            i += 1;
                            break;
                        }
                    }
                    _ => {}
                }
                i += 1;
            }
            continue;
        }
        break;
    }
    // Find the first `{` or a `;` that precedes any `{`.
    let mut j = i;
    while j < bytes.len() {
        match bytes[j] {
            b';' => return Some(j + 1),
            b'{' => {
                let mut depth = 0usize;
                while j < bytes.len() {
                    match bytes[j] {
                        b'{' => depth += 1,
                        b'}' => {
                            depth -= 1;
                            if depth == 0 {
                                return Some(j + 1);
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
                return None;
            }
            _ => j += 1,
        }
    }
    None
}

fn mark_lines(flags: &mut [bool], line_starts: &[usize], start: usize, end: usize) {
    let first = match line_starts.binary_search(&start) {
        Ok(i) => i,
        Err(i) => i - 1,
    };
    let last = match line_starts.binary_search(&end) {
        Ok(i) => i,
        Err(i) => i - 1,
    };
    for f in flags.iter_mut().take(last + 1).skip(first) {
        *f = true;
    }
}

/// Iterator over word-boundary occurrences of `word` in `haystack`
/// (identifier characters on either side disqualify a match).
pub fn ident_occurrences(haystack: &str, word: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let hb = haystack.as_bytes();
    let ident = |x: u8| x.is_ascii_alphanumeric() || x == b'_';
    let mut from = 0usize;
    while let Some(rel) = haystack[from..].find(word) {
        let at = from + rel;
        from = at + 1;
        let before_ok = at == 0 || !ident(hb[at - 1]);
        let after = at + word.len();
        let after_ok = after >= hb.len() || !ident(hb[after]);
        if before_ok && after_ok {
            out.push(at);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_comments_and_strings_preserving_offsets() {
        let src = "let a = \"un//safe\"; // unsafe here\nlet b = 'x'; /* unsafe */ let c: &'static str = \"\";\n";
        let out = strip(src);
        assert_eq!(out.len(), src.len());
        assert!(!out.contains("unsafe"));
        assert!(out.contains("'static"));
        assert_eq!(
            src.match_indices('\n').count(),
            out.match_indices('\n').count()
        );
    }

    #[test]
    fn raw_strings_and_escapes() {
        let src = "let s = r#\"unsafe \" quote\"#; let t = \"\\\"unsafe\\\"\"; let u = '\\'';";
        let out = strip(src);
        assert_eq!(out.len(), src.len());
        assert!(!out.contains("unsafe"));
    }

    #[test]
    fn test_region_marking() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\nfn c() {}\n";
        let f = SourceFile::new("rust/src/x.rs".into(), src.into());
        assert!(!f.test_lines[0]);
        assert!(f.test_lines[1]);
        assert!(f.test_lines[2]);
        assert!(f.test_lines[3]);
        assert!(f.test_lines[4]);
        assert!(!f.test_lines[5]);
    }

    #[test]
    fn word_boundaries() {
        let occ = ident_occurrences("unsafe unsafe_op_in_unsafe_fn xunsafe un_safe", "unsafe");
        assert_eq!(occ, vec![0]);
    }
}
