//! `wire-consts`: the wire-format constants scattered across
//! `transport/frame.rs`, `coding/message.rs`, `coding/batch.rs`, and
//! `coordinator/dist.rs` are cross-referenced against ONE generated table
//! (below) plus structural identities (header lengths decompose into their
//! field widths, the version window is well-formed, frame-kind bytes are
//! unique). Skewing any one constant without updating its peers — the
//! classic silent determinism breaker — fails the verifier with a diff of
//! the table.

use crate::{Finding, SourceFile, Tree};

/// The single source of truth: every wire constant and its pinned value.
/// Bumping a format version means editing this table in the same PR — which
/// is the point: the cross-file consistency argument happens here, once.
const EXPECTED: &[(&str, &str, i64)] = &[
    ("src/transport/frame.rs", "TRANSPORT_VERSION", 4),
    ("src/transport/frame.rs", "MIN_TRANSPORT_VERSION", 2),
    ("src/transport/frame.rs", "HELLO_LEN", 10),
    ("src/transport/frame.rs", "TRACE_CTX_FLAG", 0x80),
    ("src/transport/frame.rs", "TRACE_CTX_LEN", 12),
    ("src/transport/frame.rs", "PROBE_BODY_LEN", 25),
    ("src/transport/frame.rs", "TAG_PULL", 0x10),
    ("src/transport/frame.rs", "TAG_WEIGHTS", 0x11),
    ("src/transport/frame.rs", "TAG_GRAD", 0x12),
    ("src/transport/frame.rs", "TAG_SHUTDOWN", 0x13),
    ("src/transport/frame.rs", "TAG_CONFIG", 0x14),
    ("src/transport/frame.rs", "TAG_GRAD_BATCH", 0x15),
    ("src/transport/frame.rs", "TAG_WEIGHTS_BATCH", 0x16),
    ("src/transport/frame.rs", "TAG_SPARSE_REDUCE", 0x17),
    ("src/transport/frame.rs", "TAG_RING_ADDR", 0x18),
    ("src/transport/frame.rs", "TAG_PROBE", 0x19),
    ("src/coding/message.rs", "VERSION", 1),
    ("src/coding/message.rs", "HEADER_LEN", 24),
    ("src/coding/batch.rs", "BATCH_VERSION", 2),
    ("src/coding/batch.rs", "BATCH_HEADER_LEN", 12),
    ("src/coding/batch.rs", "SUB_HEADER_LEN", 17),
    ("src/coding/batch.rs", "PARAM_DELTA_FLAG", 0x80),
    ("src/coordinator/dist.rs", "CONFIG_VERSION", 7),
];

pub fn check(tree: &Tree, out: &mut Vec<Finding>) -> String {
    let mut table = String::from("wire-format constant table (found vs pinned):\n");
    let mut found: Vec<(&str, &str, Option<i64>, i64)> = Vec::new();
    for &(file, name, expected) in EXPECTED {
        let Some(f) = tree.files.iter().find(|f| f.path.ends_with(file)) else {
            continue; // fixture trees omit most files; the build catches deletions
        };
        let got = parse_const(f, name);
        found.push((file, name, got, expected));
        match got {
            None => out.push(Finding {
                rule: "wire-consts",
                path: f.path.clone(),
                line: 0,
                msg: format!("constant `{name}` not found (or not an integer literal)"),
            }),
            Some(v) if v != expected => out.push(Finding {
                rule: "wire-consts",
                path: f.path.clone(),
                line: 0,
                msg: format!(
                    "`{name}` = {v} but the verifier table pins {expected} — \
                     if the format changed on purpose, update verifier/src/rules/wire.rs"
                ),
            }),
            Some(_) => {}
        }
    }
    for (file, name, got, expected) in &found {
        let shown = got.map_or("<missing>".to_string(), |v| format!("{v:#x}"));
        table.push_str(&format!(
            "  {file:28} {name:24} {shown:>10}  (pinned {expected:#x})\n"
        ));
    }

    // Relational invariants on whatever the tree actually contains.
    let get = |name: &str| found.iter().find(|r| r.1 == name).and_then(|r| r.2);
    if let (Some(min), Some(max)) = (get("MIN_TRANSPORT_VERSION"), get("TRANSPORT_VERSION")) {
        if min > max {
            out.push(Finding {
                rule: "wire-consts",
                path: "rust/src/transport/frame.rs".into(),
                line: 0,
                msg: format!(
                    "version window inverted: MIN_TRANSPORT_VERSION ({min}) > \
                     TRANSPORT_VERSION ({max})"
                ),
            });
        }
        if let Some(f) = tree.files.iter().find(|f| f.path.ends_with("src/transport/frame.rs"))
        {
            match supports_batch_threshold(f) {
                Some(t) if t < min || t > max => out.push(Finding {
                    rule: "wire-consts",
                    path: f.path.clone(),
                    line: 0,
                    msg: format!(
                        "supports_batch threshold {t} outside the accepted \
                         version window [{min}, {max}]"
                    ),
                }),
                None => out.push(Finding {
                    rule: "wire-consts",
                    path: f.path.clone(),
                    line: 0,
                    msg: "could not locate the `version >= N` literal in supports_batch"
                        .into(),
                }),
                Some(_) => {}
            }
        }
    }
    // Frame-kind bytes must be unique.
    let tags: Vec<(&str, i64)> = found
        .iter()
        .filter(|r| r.1.starts_with("TAG_"))
        .filter_map(|r| r.2.map(|v| (r.1, v)))
        .collect();
    for (i, &(name_a, a)) in tags.iter().enumerate() {
        for &(name_b, b) in &tags[i + 1..] {
            if a == b {
                out.push(Finding {
                    rule: "wire-consts",
                    path: "rust/src/transport/frame.rs".into(),
                    line: 0,
                    msg: format!("frame tags `{name_a}` and `{name_b}` collide at {a:#x}"),
                });
            }
        }
    }
    // Header lengths decompose into their documented field widths.
    let identities: &[(&str, i64, &str)] = &[
        ("HELLO_LEN", 4 + 1 + 4 + 1, "magic + version + worker_id + codec"),
        (
            "HEADER_LEN",
            4 + 1 + 1 + 1 + 1 + 4 + 4 + 4 + 4,
            "magic + ver + enc + ka + kb + d + nnz_a + nnz_b + shared_mag",
        ),
        (
            "BATCH_HEADER_LEN",
            4 + 1 + 1 + 1 + 1 + 4,
            "magic + ver + codec + ka + kb + nlayers",
        ),
        (
            "SUB_HEADER_LEN",
            1 + 4 + 4 + 4 + 4,
            "enc + d + nnz_a + nnz_b + shared_mag",
        ),
    ];
    for &(name, sum, fields) in identities {
        let home = EXPECTED
            .iter()
            .find(|e| e.1 == name)
            .map_or("", |e| e.0)
            .to_string();
        if let Some(v) = get(name) {
            if v != sum {
                out.push(Finding {
                    rule: "wire-consts",
                    path: home,
                    line: 0,
                    msg: format!("`{name}` = {v} but its fields ({fields}) sum to {sum}"),
                });
            }
        }
    }
    table
}

/// Parse `const NAME: <ty> = <int literal>;` from stripped code. Returns
/// `None` when absent or when the initializer is not a plain integer.
fn parse_const(f: &SourceFile, name: &str) -> Option<i64> {
    for at in crate::strip::ident_occurrences(&f.code, name) {
        // Must look like a const definition: preceding token is `const`.
        let before = f.code[..at].trim_end();
        if !before.ends_with("const") {
            continue;
        }
        let after = &f.code[at + name.len()..];
        let eq = after.find('=')?;
        let rest = after[eq + 1..].trim_start();
        return parse_int(rest);
    }
    None
}

fn parse_int(s: &str) -> Option<i64> {
    let s = s.trim_start();
    let (digits, radix) = if let Some(hex) = s.strip_prefix("0x") {
        (hex, 16)
    } else {
        (s, 10)
    };
    let mut end = 0usize;
    for (i, c) in digits.char_indices() {
        if c.is_digit(radix) || c == '_' {
            end = i + c.len_utf8();
        } else {
            break;
        }
    }
    if end == 0 {
        return None;
    }
    let lit: String = digits[..end].chars().filter(|&c| c != '_').collect();
    // Reject expressions (`1 << 28`): the literal must be followed by an
    // optional type suffix and then `;`.
    let tail = digits[end..].trim_start();
    let tail = tail
        .trim_start_matches(|c: char| c.is_ascii_alphanumeric())
        .trim_start();
    if !tail.starts_with(';') {
        return None;
    }
    i64::from_str_radix(&lit, radix).ok()
}

/// Extract `N` from `self.version >= N` inside `fn supports_batch`.
fn supports_batch_threshold(f: &SourceFile) -> Option<i64> {
    let at = f.code.find("fn supports_batch")?;
    let window = &f.code[at..f.code.len().min(at + 400)];
    let ge = window.find(">=")?;
    let rest = window[ge + 2..].trim_start();
    let mut end = 0usize;
    for (i, c) in rest.char_indices() {
        if c.is_ascii_digit() {
            end = i + 1;
        } else {
            break;
        }
    }
    if end == 0 {
        return None;
    }
    rest[..end].parse().ok()
}
