//! `thread-spawn`: raw `thread::spawn` is allowed only at the sites that
//! own thread lifecycles — the `ShardPool` workers, the transport `Mux`
//! reader threads, the dist coordinator's process watchdog, the telemetry
//! `/metrics` responder's accept loop, and the `gsparse::sync` shim itself
//! (whose model scheduler spawns the threads it controls). Everything else
//! must go through `ShardPool` or `thread::scope`
//! so no detached thread can outlive the borrows it captures.

use crate::{Finding, Tree};

/// Files (suffix match) where `thread::spawn` is legitimate.
const ALLOWED: &[&str] = &[
    "src/sync/",
    "src/sparsify/pool.rs",
    "src/transport/mod.rs",
    "src/coordinator/dist.rs",
    "src/telemetry/http.rs",
];

pub fn check(tree: &Tree, out: &mut Vec<Finding>) {
    for f in &tree.files {
        if !f.path.contains("src/") {
            continue;
        }
        if ALLOWED.iter().any(|a| f.path.contains(a)) {
            continue;
        }
        let mut from = 0usize;
        while let Some(rel) = f.code[from..].find("thread::spawn") {
            let at = from + rel;
            from = at + 1;
            if f.is_test_at(at) {
                continue;
            }
            out.push(Finding {
                rule: "thread-spawn",
                path: f.path.clone(),
                line: f.line_of(at),
                msg: "`thread::spawn` outside the allow-listed thread owners \
                      (use ShardPool, thread::scope, or gsparse::sync::thread)"
                    .into(),
            });
        }
    }
}
