//! `trace-hotpath`: functions annotated `// verifier: hot-path` must stay
//! allocation-free and lock-free — no `Instant::now` (unless the marker
//! says `(clock-ok)`, for the two span entry points whose whole job is to
//! read the clock), no blocking `.lock(`, and none of the common allocating
//! calls. The rule also *requires* the markers on the four trace hot-path
//! functions (`record`, `Ring::push`, `span`, `counter`) so the annotation
//! itself cannot silently disappear.

use crate::strip::ident_occurrences;
use crate::{Finding, SourceFile, Tree};

const MARKER: &str = "verifier: hot-path";

/// Substrings that mean "this allocates" at the call-site level.
const ALLOCATING: &[&str] = &[
    "Vec::new",
    "vec!",
    "Box::new",
    "String::new",
    "String::from",
    "format!",
    ".to_string(",
    ".to_owned(",
    ".to_vec(",
    "with_capacity",
    ".collect(",
    "push_str",
];

/// Functions in `rust/src/trace/mod.rs` that must carry the marker.
const REQUIRED_TRACE_FNS: &[&str] = &["record", "push", "span", "counter"];

pub fn check(tree: &Tree, out: &mut Vec<Finding>) {
    for f in &tree.files {
        if !f.path.contains("src/") {
            continue;
        }
        let mut marked: Vec<String> = Vec::new();
        for line in 1..=f.lines() {
            let raw = f.raw_line(line);
            let Some(pos) = raw.find(MARKER) else {
                continue;
            };
            let clock_ok = raw[pos..].contains("(clock-ok)");
            match fn_after_line(f, line) {
                Some((name, body_start, body_end)) => {
                    marked.push(name.clone());
                    scan_body(f, &name, body_start, body_end, clock_ok, out);
                }
                None => out.push(Finding {
                    rule: "trace-hotpath",
                    path: f.path.clone(),
                    line,
                    msg: "hot-path marker not followed by a function".into(),
                }),
            }
        }
        if f.path.ends_with("src/trace/mod.rs") {
            for required in REQUIRED_TRACE_FNS {
                if !marked.iter().any(|m| m == required) {
                    out.push(Finding {
                        rule: "trace-hotpath",
                        path: f.path.clone(),
                        line: 0,
                        msg: format!(
                            "trace fn `{required}` lost its `// {MARKER}` marker"
                        ),
                    });
                }
            }
        }
    }
}

/// Find the first `fn` at or after the start of `line + 1` in stripped
/// code; return its name and body byte range (inside the braces).
fn fn_after_line(f: &SourceFile, line: usize) -> Option<(String, usize, usize)> {
    let from = *f.line_starts.get(line)?; // start of the following line
    let code = &f.code;
    let fn_at = ident_occurrences(&code[from..], "fn")
        .first()
        .map(|&o| from + o)?;
    let bytes = code.as_bytes();
    // Function name: first identifier after `fn`.
    let mut i = fn_at + 2;
    while i < bytes.len() && (bytes[i] as char).is_whitespace() {
        i += 1;
    }
    let name_start = i;
    while i < bytes.len() && (bytes[i].is_ascii_alphanumeric() || bytes[i] == b'_') {
        i += 1;
    }
    let name = code[name_start..i].to_string();
    if name.is_empty() {
        return None;
    }
    // Body: first `{` after the `fn` keyword, to its matching `}`.
    let open = fn_at + code[fn_at..].find('{')?;
    let mut depth = 0usize;
    let mut j = open;
    while j < bytes.len() {
        match bytes[j] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return Some((name, open + 1, j));
                }
            }
            _ => {}
        }
        j += 1;
    }
    None
}

fn scan_body(
    f: &SourceFile,
    name: &str,
    start: usize,
    end: usize,
    clock_ok: bool,
    out: &mut Vec<Finding>,
) {
    let body = &f.code[start..end];
    let mut flag = |pat: &str, what: &str| {
        if let Some(off) = body.find(pat) {
            out.push(Finding {
                rule: "trace-hotpath",
                path: f.path.clone(),
                line: f.line_of(start + off),
                msg: format!("hot-path fn `{name}` contains {what} (`{pat}`)"),
            });
        }
    };
    if !clock_ok {
        flag("Instant::now", "a clock read");
    }
    flag(".lock(", "a blocking lock (use try_lock and drop on contention)");
    for pat in ALLOCATING {
        flag(pat, "an allocating call");
    }
}
