//! `safety-comment`: every `unsafe` keyword in non-test `rust/src` code —
//! blocks, fns, and impls alike — must carry a `// SAFETY:` justification:
//! on the same line, or anywhere in the contiguous run of comment lines
//! directly above it (blank lines and `#[...]` attribute lines may sit in
//! between). The lifetime-erasing transmute in `sparsify/pool.rs` is
//! exactly the kind of site whose justification must never rot away from
//! the code.

use crate::strip::ident_occurrences;
use crate::{Finding, Tree};

/// True when the raw line may appear between an `unsafe` and its SAFETY
/// comment block: a comment, an attribute, or blank.
fn is_gap_line(raw: &str) -> bool {
    let t = raw.trim_start();
    t.is_empty() || t.starts_with("//") || t.starts_with("#[") || t.starts_with("#!")
}

pub fn check(tree: &Tree, out: &mut Vec<Finding>) {
    for f in &tree.files {
        if !f.path.contains("src/") {
            continue;
        }
        for at in ident_occurrences(&f.code, "unsafe") {
            if f.is_test_at(at) {
                continue;
            }
            let line = f.line_of(at);
            let mut documented = f.raw_line(line).contains("SAFETY:");
            let mut l = line;
            while !documented && l > 1 {
                l -= 1;
                let raw = f.raw_line(l);
                if raw.contains("SAFETY:") {
                    documented = true;
                } else if !is_gap_line(raw) {
                    break;
                }
            }
            if !documented {
                out.push(Finding {
                    rule: "safety-comment",
                    path: f.path.clone(),
                    line,
                    msg: "`unsafe` without a `// SAFETY:` comment on it or in the \
                          comment block directly above"
                        .into(),
                });
            }
        }
    }
}
