//! `stage-coverage` / `wire-error-tests`: the observability and adversarial
//! surfaces must stay total. Every `trace::Stage` variant needs at least one
//! probe site outside `trace/mod.rs` (a stage nobody records is a dead
//! column in every export), the `STAGES` table must list each variant
//! exactly once, and every `coding::WireError` variant needs at least one
//! hostile-decode test under `rust/tests/` naming it — the rule that found
//! the gaps `tests/invariants.rs` now closes.

use crate::strip::ident_occurrences;
use crate::{Finding, SourceFile, Tree};

pub fn check(tree: &Tree, out: &mut Vec<Finding>) {
    if let Some(f) = tree.files.iter().find(|f| f.path.ends_with("src/trace/mod.rs")) {
        check_stages(tree, f, out);
    }
    if let Some(f) = tree
        .files
        .iter()
        .find(|f| f.path.ends_with("src/coding/message.rs"))
    {
        check_wire_errors(tree, f, out);
    }
}

fn check_stages(tree: &Tree, f: &SourceFile, out: &mut Vec<Finding>) {
    let Some(variants) = enum_variants(f, "Stage") else {
        out.push(Finding {
            rule: "stage-coverage",
            path: f.path.clone(),
            line: 0,
            msg: "could not parse `enum Stage`".into(),
        });
        return;
    };
    // The STAGES table must enumerate each variant exactly once.
    if let Some(body) = stages_array_body(f) {
        for v in &variants {
            let n = ident_occurrences(body, v).len();
            if n != 1 {
                out.push(Finding {
                    rule: "stage-coverage",
                    path: f.path.clone(),
                    line: 0,
                    msg: format!("`STAGES` lists `Stage::{v}` {n} times (want exactly 1)"),
                });
            }
        }
    } else {
        out.push(Finding {
            rule: "stage-coverage",
            path: f.path.clone(),
            line: 0,
            msg: "could not locate the `STAGES` array initializer".into(),
        });
    }
    // Every variant needs a probe site somewhere else in the tree.
    for v in &variants {
        let probe = format!("Stage::{v}");
        let probed = tree
            .files
            .iter()
            .filter(|other| !other.path.ends_with("src/trace/mod.rs"))
            .any(|other| other.code.contains(&probe));
        if !probed {
            out.push(Finding {
                rule: "stage-coverage",
                path: f.path.clone(),
                line: 0,
                msg: format!("`Stage::{v}` has no probe site outside trace/mod.rs"),
            });
        }
    }
}

fn check_wire_errors(tree: &Tree, f: &SourceFile, out: &mut Vec<Finding>) {
    let Some(variants) = enum_variants(f, "WireError") else {
        out.push(Finding {
            rule: "wire-error-tests",
            path: f.path.clone(),
            line: 0,
            msg: "could not parse `enum WireError`".into(),
        });
        return;
    };
    for v in &variants {
        let pat = format!("WireError::{v}");
        let tested = tree
            .files
            .iter()
            .filter(|t| t.path.contains("rust/tests/") || t.path.starts_with("tests/"))
            .any(|t| t.code.contains(&pat));
        if !tested {
            out.push(Finding {
                rule: "wire-error-tests",
                path: f.path.clone(),
                line: 0,
                msg: format!(
                    "`WireError::{v}` has no adversarial decode test under rust/tests/"
                ),
            });
        }
    }
}

/// Parse the variant names of `enum <name>` from stripped code.
fn enum_variants(f: &SourceFile, name: &str) -> Option<Vec<String>> {
    let code = &f.code;
    let mut def = None;
    for at in ident_occurrences(code, name) {
        if code[..at].trim_end().ends_with("enum") {
            def = Some(at);
            break;
        }
    }
    let at = def?;
    let open = at + code[at..].find('{')?;
    let bytes = code.as_bytes();
    let mut depth = 0usize;
    let mut close = open;
    for (j, &b) in bytes.iter().enumerate().skip(open) {
        match b {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    close = j;
                    break;
                }
            }
            _ => {}
        }
    }
    let body = &code[open + 1..close];
    let mut variants = Vec::new();
    let mut expect_variant = true;
    let mut depth = 0i32;
    let b = body.as_bytes();
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        match c {
            b'{' | b'(' | b'[' => {
                depth += 1;
                i += 1;
            }
            b'}' | b')' | b']' => {
                depth -= 1;
                i += 1;
            }
            b',' if depth == 0 => {
                expect_variant = true;
                i += 1;
            }
            b'#' if depth == 0 && i + 1 < b.len() && b[i + 1] == b'[' => {
                // Skip an attribute.
                let mut d = 0usize;
                while i < b.len() {
                    match b[i] {
                        b'[' => d += 1,
                        b']' => {
                            d -= 1;
                            if d == 0 {
                                i += 1;
                                break;
                            }
                        }
                        _ => {}
                    }
                    i += 1;
                }
            }
            _ if expect_variant
                && depth == 0
                && (c.is_ascii_alphabetic() || c == b'_') =>
            {
                let start = i;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                variants.push(body[start..i].to_string());
                expect_variant = false;
            }
            _ => i += 1,
        }
    }
    Some(variants)
}

/// The text of the `STAGES` array initializer (`= [ ... ]`).
fn stages_array_body(f: &SourceFile) -> Option<&str> {
    let code = &f.code;
    for at in ident_occurrences(code, "STAGES") {
        if !code[..at].trim_end().ends_with("const") {
            continue;
        }
        let eq = at + code[at..].find('=')?;
        let open = eq + code[eq..].find('[')?;
        let close = open + code[open..].find(']')?;
        return Some(&code[open + 1..close]);
    }
    None
}
