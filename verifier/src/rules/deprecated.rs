//! `deprecated-use`: the `#[deprecated]` config shims (`TrainOptions`,
//! `PsConfig`, `DistConfig`, `Cluster::new`, ...) exist for downstream
//! callers only — code inside `src/` must use the `Session` surface.
//! rustc's own lint already warns, but a warning inside an
//! `#[allow(deprecated)]` re-export region is invisible; this rule makes
//! the boundary explicit: a deprecated ident may appear only in its
//! defining file, under an `#[allow(deprecated)]` item (the intentional
//! re-export/shim sites), in `use` declarations, or in tests.
//!
//! Matching is name-based, so precision is deliberate: type-level shims
//! (`struct`/`enum`/`type`/`trait`) match their bare ident anywhere, while
//! `fn` shims — whose names (`new`, `build`, `train_convex`) collide with
//! unrelated live items — match only path-qualified uses: `Type::name` for
//! methods, `module::name` for free functions. Unqualified calls of a
//! deprecated free fn are left to rustc's lint.

use crate::strip::{ident_occurrences, item_end_after};
use crate::{Finding, SourceFile, Tree};

enum Needle {
    /// Bare identifier, word-boundary matched (type-level shims).
    Ident(String),
    /// `prefix::name`, boundary-checked at both ends (fn shims).
    Qualified(String),
}

impl Needle {
    fn text(&self) -> &str {
        match self {
            Needle::Ident(s) | Needle::Qualified(s) => s,
        }
    }
}

pub fn check(tree: &Tree, out: &mut Vec<Finding>) {
    // Inventory: (defining file, needle) for every `#[deprecated]` item.
    let mut shims: Vec<(String, Needle)> = Vec::new();
    for f in &tree.files {
        if !f.path.contains("src/") {
            continue;
        }
        let mut from = 0usize;
        while let Some(rel) = f.code[from..].find("#[deprecated") {
            let at = from + rel;
            from = at + 1;
            if f.is_test_at(at) {
                continue;
            }
            let Some((kw, name)) = deprecated_item(&f.code, at) else {
                continue;
            };
            let needle = if kw == "fn" {
                let prefix = match enclosing_impl_type(&f.code, at) {
                    Some(ty) => ty,
                    None => match module_of(&f.path) {
                        Some(m) => m,
                        None => continue,
                    },
                };
                Needle::Qualified(format!("{prefix}::{name}"))
            } else {
                Needle::Ident(name)
            };
            if !shims.iter().any(|(_, n)| n.text() == needle.text()) {
                shims.push((f.path.clone(), needle));
            }
        }
    }
    if shims.is_empty() {
        return;
    }
    for f in &tree.files {
        if !f.path.contains("src/") {
            continue;
        }
        let allowed = allowed_lines(f);
        for (home, needle) in &shims {
            if &f.path == home {
                continue; // the shim's own file may reference it freely
            }
            let hits = match needle {
                Needle::Ident(name) => ident_occurrences(&f.code, name),
                Needle::Qualified(path) => qualified_occurrences(&f.code, path),
            };
            for at in hits {
                if f.is_test_at(at) {
                    continue;
                }
                let line = f.line_of(at);
                if allowed[line - 1] {
                    continue;
                }
                let trimmed = f.raw_line(line).trim_start();
                if trimmed.starts_with("use ") || trimmed.starts_with("pub use ") {
                    continue;
                }
                out.push(Finding {
                    rule: "deprecated-use",
                    path: f.path.clone(),
                    line,
                    msg: format!(
                        "use of deprecated shim `{}` (defined in {home}) — \
                         migrate to the Session surface or mark the shim site \
                         #[allow(deprecated)]",
                        needle.text()
                    ),
                });
            }
        }
    }
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Occurrences of a `prefix::name` path with identifier boundaries on both
/// sides (so `MyCluster::new` never matches a `Cluster::new` needle).
fn qualified_occurrences(hay: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let hb = hay.as_bytes();
    let mut from = 0usize;
    while let Some(rel) = hay[from..].find(needle) {
        let at = from + rel;
        from = at + 1;
        let before_ok = at == 0 || !is_ident_byte(hb[at - 1]);
        let end = at + needle.len();
        let after_ok = end >= hb.len() || !is_ident_byte(hb[end]);
        if before_ok && after_ok {
            out.push(at);
        }
    }
    out
}

/// Rust module name a file's free items live under (`sparsify/mod.rs` →
/// `sparsify`, `coordinator/sync.rs` → `sync`).
fn module_of(path: &str) -> Option<String> {
    let mut parts = path.rsplit('/');
    let file = parts.next()?;
    if file == "mod.rs" {
        parts.next().map(str::to_string)
    } else if file == "lib.rs" || file == "main.rs" {
        None // crate-root free fns have no stable path prefix
    } else {
        Some(file.strip_suffix(".rs").unwrap_or(file).to_string())
    }
}

/// The keyword and identifier of the item a `#[deprecated...]` attribute at
/// `attr_start` is attached to.
fn deprecated_item(code: &str, attr_start: usize) -> Option<(&'static str, String)> {
    let bytes = code.as_bytes();
    // Skip past the attribute's closing bracket.
    let mut i = attr_start;
    let mut d = 0usize;
    while i < bytes.len() {
        match bytes[i] {
            b'[' => d += 1,
            b']' => {
                d -= 1;
                if d == 0 {
                    i += 1;
                    break;
                }
            }
            _ => {}
        }
        i += 1;
    }
    let window = &code[i..bytes.len().min(i + 400)];
    let mut best: Option<usize> = None;
    let mut best_kw: &'static str = "";
    for kw in ["fn", "struct", "enum", "trait", "type", "mod", "const", "static"] {
        if let Some(&at) = ident_occurrences(window, kw).first() {
            let earlier = match best {
                None => true,
                Some(b) => at < b,
            };
            if earlier {
                best = Some(at);
                best_kw = kw;
            }
        }
    }
    let mut j = best? + best_kw.len();
    let wb = window.as_bytes();
    while j < wb.len() && (wb[j] as char).is_whitespace() {
        j += 1;
    }
    let start = j;
    while j < wb.len() && is_ident_byte(wb[j]) {
        j += 1;
    }
    (j > start).then(|| (best_kw, window[start..j].to_string()))
}

/// Self type of the innermost `impl` block enclosing `pos`, if any
/// (`impl Cluster` / `impl<T> Foo<T>` / `impl Debug for Bar` all resolve to
/// the implementing type's final path segment).
fn enclosing_impl_type(code: &str, pos: usize) -> Option<String> {
    let bytes = code.as_bytes();
    let mut best: Option<(usize, String)> = None;
    for at in ident_occurrences(code, "impl") {
        if at >= pos {
            break;
        }
        let Some(rel) = code[at..].find('{') else {
            continue;
        };
        let open = at + rel;
        if open >= pos {
            continue;
        }
        // Matching close of the impl block's brace.
        let mut depth = 0usize;
        let mut j = open;
        let mut close = None;
        while j < bytes.len() {
            match bytes[j] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        close = Some(j);
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        let Some(close) = close else { continue };
        if pos >= close {
            continue;
        }
        if let Some(name) = impl_header_type(&code[at + 4..open]) {
            let replace = match &best {
                None => true,
                Some((b, _)) => at > *b, // innermost wins
            };
            if replace {
                best = Some((at, name));
            }
        }
    }
    best.map(|(_, name)| name)
}

/// Extract the self-type name from the text between `impl` and `{`.
fn impl_header_type(header: &str) -> Option<String> {
    let mut h = header.trim();
    if let Some(p) = h.find(" for ") {
        h = h[p + 5..].trim();
    } else if h.starts_with('<') {
        // Skip the generic parameter list after `impl`.
        let mut d = 0usize;
        let mut cut = h.len();
        for (k, ch) in h.char_indices() {
            match ch {
                '<' => d += 1,
                '>' => {
                    d -= 1;
                    if d == 0 {
                        cut = k + 1;
                        break;
                    }
                }
                _ => {}
            }
        }
        h = h[cut..].trim();
    }
    let end = h
        .find(|c: char| c == '<' || c.is_whitespace())
        .unwrap_or(h.len());
    let name = h[..end].rsplit("::").next().unwrap_or("");
    (!name.is_empty()).then(|| name.to_string())
}

/// Lines covered by `#[allow(deprecated)]` (attribute through the end of
/// its item), or the whole file for `#![allow(deprecated)]`.
fn allowed_lines(f: &SourceFile) -> Vec<bool> {
    let mut allowed = vec![false; f.lines()];
    let bytes = f.code.as_bytes();
    let mut from = 0usize;
    while let Some(rel) = f.code[from..].find("allow(deprecated") {
        let at = from + rel;
        from = at + 1;
        let Some(hash) = f.code[..at].rfind('#') else {
            continue;
        };
        if bytes.get(hash + 1) == Some(&b'!') {
            // Inner attribute: whole file.
            for a in allowed.iter_mut() {
                *a = true;
            }
            return allowed;
        }
        // Outer attribute: match its `]`, then extend over the item.
        let mut i = hash;
        let mut d = 0usize;
        while i < bytes.len() {
            match bytes[i] {
                b'[' => d += 1,
                b']' => {
                    d -= 1;
                    if d == 0 {
                        i += 1;
                        break;
                    }
                }
                _ => {}
            }
            i += 1;
        }
        let end = item_end_after(bytes, i).unwrap_or(bytes.len());
        let first = f.line_of(hash) - 1;
        let last = f.line_of(end.saturating_sub(1)) - 1;
        for a in allowed.iter_mut().take(last + 1).skip(first) {
            *a = true;
        }
    }
    allowed
}
