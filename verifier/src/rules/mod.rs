//! The repo-specific lint rules. Each module exposes
//! `check(&Tree, &mut Vec<Finding>)` (the wire rule also returns the
//! generated constant table). Rule ids are stable strings so CI output and
//! the fixture tests can key on them:
//!
//! | id                 | invariant                                             |
//! |--------------------|-------------------------------------------------------|
//! | `safety-comment`   | every `unsafe` in `src/` carries a `// SAFETY:` note  |
//! | `thread-spawn`     | `thread::spawn` only at allow-listed sites            |
//! | `trace-hotpath`    | marked hot-path fns: no clocks/locks/allocations      |
//! | `wire-consts`      | wire-format constants match the generated table       |
//! | `stage-coverage`   | every `trace::Stage` variant has a probe site         |
//! | `wire-error-tests` | every `WireError` variant has an adversarial test     |
//! | `deprecated-use`   | no use of `#[deprecated]` shims inside `src/`         |

pub mod coverage;
pub mod deprecated;
pub mod hotpath;
pub mod safety;
pub mod spawn;
pub mod wire;
