//! Repo-invariant lint pass for the gsparse tree.
//!
//! `cargo run -p verifier` scans `rust/src` + `rust/tests` and enforces the
//! hand-maintained invariants the reproduction's determinism claims rest on
//! (see each rule module). The same engine runs as a tier-1 test
//! (`verifier/tests/tree.rs`), so `cargo test -q` fails on any violation,
//! and against synthetic fixture trees (`verifier/tests/fixtures.rs`) to
//! prove each rule actually fires.

pub mod rules;
pub mod strip;

use std::fmt;
use std::io;
use std::path::{Path, PathBuf};

pub use strip::SourceFile;

/// A scanned source tree (repo-relative paths, forward slashes).
pub struct Tree {
    pub files: Vec<SourceFile>,
}

impl Tree {
    /// Build a tree from in-memory `(path, contents)` pairs — the fixture
    /// tests' entry point.
    pub fn from_files(files: Vec<(String, String)>) -> Self {
        Self {
            files: files
                .into_iter()
                .map(|(p, s)| SourceFile::new(p, s))
                .collect(),
        }
    }

    /// Load every `.rs` file under `<root>/rust/src` and `<root>/rust/tests`.
    pub fn load(root: &Path) -> io::Result<Self> {
        let mut paths: Vec<PathBuf> = Vec::new();
        for sub in ["rust/src", "rust/tests"] {
            collect_rs(&root.join(sub), &mut paths)?;
        }
        paths.sort();
        let mut files = Vec::with_capacity(paths.len());
        for p in paths {
            let raw = std::fs::read_to_string(&p)?;
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .to_string_lossy()
                .replace('\\', "/");
            files.push(SourceFile::new(rel, raw));
        }
        Ok(Self { files })
    }

    pub fn get(&self, path: &str) -> Option<&SourceFile> {
        self.files.iter().find(|f| f.path == path)
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// One rule violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Stable rule identifier (`safety-comment`, `wire-consts`, ...).
    pub rule: &'static str,
    pub path: String,
    /// 1-based line, or 0 when the finding is tree-level.
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.line == 0 {
            write!(f, "[{}] {}: {}", self.rule, self.path, self.msg)
        } else {
            write!(f, "[{}] {}:{}: {}", self.rule, self.path, self.line, self.msg)
        }
    }
}

/// The full report: findings plus the generated wire-constant table.
pub struct Report {
    pub findings: Vec<Finding>,
    pub wire_table: String,
}

impl Report {
    pub fn passed(&self) -> bool {
        self.findings.is_empty()
    }

    /// Findings for one rule id (fixture tests filter with this).
    pub fn by_rule(&self, rule: &str) -> Vec<&Finding> {
        self.findings.iter().filter(|f| f.rule == rule).collect()
    }

    /// Human-readable report body (what the binary prints and uploads).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("gsparse repo-invariant verifier\n");
        out.push_str("===============================\n\n");
        out.push_str(&self.wire_table);
        out.push('\n');
        if self.findings.is_empty() {
            out.push_str("OK: all invariants hold.\n");
        } else {
            out.push_str(&format!("{} violation(s):\n", self.findings.len()));
            for f in &self.findings {
                out.push_str(&format!("  {f}\n"));
            }
        }
        out
    }
}

/// Run every rule over the tree.
pub fn run_all(tree: &Tree) -> Report {
    let mut findings = Vec::new();
    rules::safety::check(tree, &mut findings);
    rules::spawn::check(tree, &mut findings);
    rules::hotpath::check(tree, &mut findings);
    let wire_table = rules::wire::check(tree, &mut findings);
    rules::coverage::check(tree, &mut findings);
    rules::deprecated::check(tree, &mut findings);
    findings.sort_by(|a, b| {
        (a.rule, &a.path, a.line, &a.msg).cmp(&(b.rule, &b.path, b.line, &b.msg))
    });
    Report {
        findings,
        wire_table,
    }
}
