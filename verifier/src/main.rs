//! `cargo run -p verifier` — scan the repo's `rust/` tree and enforce the
//! invariants described in `verifier::rules`. Exit code 1 on any violation.
//! Set `VERIFIER_OUT=<path>` to also write the report to a file (CI uploads
//! it as an artifact).

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    // The binary lives at <repo>/verifier; the scanned tree at <repo>/rust.
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("verifier crate sits inside the repo")
        .to_path_buf();
    let tree = match verifier::Tree::load(&root) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("verifier: cannot read {}: {e}", root.display());
            return ExitCode::FAILURE;
        }
    };
    let report = verifier::run_all(&tree);
    let rendered = report.render();
    print!("{rendered}");
    println!(
        "scanned {} files, {} finding(s)",
        tree.files.len(),
        report.findings.len()
    );
    if let Ok(out_path) = std::env::var("VERIFIER_OUT") {
        if let Err(e) = std::fs::write(&out_path, &rendered) {
            eprintln!("verifier: cannot write {out_path}: {e}");
            return ExitCode::FAILURE;
        }
    }
    if report.passed() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
