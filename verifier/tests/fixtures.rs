//! Seeded-violation fixtures: each test feeds the verifier a synthetic tree
//! containing exactly the defect a rule exists to catch, and asserts the
//! rule fires (and that the clean twin passes). This is the acceptance
//! criterion that the lint pass "demonstrably fails" — without it a rule
//! could rot into a no-op and nobody would notice.

use verifier::{run_all, Tree};

fn tree(files: &[(&str, &str)]) -> Tree {
    Tree::from_files(
        files
            .iter()
            .map(|(p, s)| (p.to_string(), s.to_string()))
            .collect(),
    )
}

#[test]
fn missing_safety_comment_is_flagged() {
    let bad = tree(&[(
        "rust/src/demo.rs",
        "pub fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n",
    )]);
    let report = run_all(&bad);
    let hits = report.by_rule("safety-comment");
    assert_eq!(hits.len(), 1, "expected exactly one finding: {:?}", hits);
    assert_eq!(hits[0].line, 2);

    let good = tree(&[(
        "rust/src/demo.rs",
        "pub fn f(p: *const u8) -> u8 {\n    // SAFETY: caller guarantees p is valid.\n    unsafe { *p }\n}\n",
    )]);
    assert!(run_all(&good).by_rule("safety-comment").is_empty());
}

#[test]
fn safety_rule_ignores_comments_strings_and_tests() {
    let t = tree(&[(
        "rust/src/demo.rs",
        concat!(
            "// this mentions unsafe in prose only\n",
            "pub const S: &str = \"unsafe\";\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    fn t() { unsafe { std::hint::unreachable_unchecked() } }\n",
            "}\n",
        ),
    )]);
    assert!(run_all(&t).by_rule("safety-comment").is_empty());
}

#[test]
fn stray_thread_spawn_is_flagged_but_allowlisted_sites_pass() {
    let bad = tree(&[(
        "rust/src/widget.rs",
        "pub fn go() { std::thread::spawn(|| {}); }\n",
    )]);
    assert_eq!(run_all(&bad).by_rule("thread-spawn").len(), 1);

    let good = tree(&[(
        "rust/src/sparsify/pool.rs",
        "pub fn go() { std::thread::spawn(|| {}); }\n",
    )]);
    assert!(run_all(&good).by_rule("thread-spawn").is_empty());
}

/// A frame.rs fixture with every pinned constant present and `min` as the
/// accepted-window floor.
fn frame_src(min: u8) -> String {
    format!(
        concat!(
            "pub const TRANSPORT_VERSION: u8 = 4;\n",
            "pub const MIN_TRANSPORT_VERSION: u8 = {};\n",
            "pub const HELLO_LEN: usize = 10;\n",
            "pub const TRACE_CTX_FLAG: u8 = 0x80;\n",
            "pub const TRACE_CTX_LEN: usize = 12;\n",
            "pub const PROBE_BODY_LEN: usize = 25;\n",
            "const TAG_PULL: u8 = 0x10;\n",
            "const TAG_WEIGHTS: u8 = 0x11;\n",
            "const TAG_GRAD: u8 = 0x12;\n",
            "const TAG_SHUTDOWN: u8 = 0x13;\n",
            "const TAG_CONFIG: u8 = 0x14;\n",
            "const TAG_GRAD_BATCH: u8 = 0x15;\n",
            "const TAG_WEIGHTS_BATCH: u8 = 0x16;\n",
            "const TAG_SPARSE_REDUCE: u8 = 0x17;\n",
            "const TAG_RING_ADDR: u8 = 0x18;\n",
            "const TAG_PROBE: u8 = 0x19;\n",
            "impl Hello {{ pub fn supports_batch(&self) -> bool {{ self.version >= 3 }} }}\n",
        ),
        min
    )
}

#[test]
fn skewed_version_constant_is_flagged() {
    // MIN above MAX: both the pinned-table check and the window identity
    // must fire.
    let bad = tree(&[("rust/src/transport/frame.rs", frame_src(5).as_str())]);
    let report = run_all(&bad);
    let hits = report.by_rule("wire-consts");
    assert!(
        hits.iter().any(|f| f.msg.contains("window inverted")),
        "missing window finding: {:?}",
        hits
    );
    assert!(
        hits.iter().any(|f| f.msg.contains("MIN_TRANSPORT_VERSION")
            && f.msg.contains("pins")),
        "missing pinned-value finding: {:?}",
        hits
    );

    let good = tree(&[("rust/src/transport/frame.rs", frame_src(2).as_str())]);
    assert!(run_all(&good).by_rule("wire-consts").is_empty());
}

#[test]
fn unprobed_stage_variant_is_flagged() {
    let src = concat!(
        "pub enum Stage {\n    Round = 0,\n    Solve = 1,\n}\n",
        "pub const STAGES: [Stage; 2] = [Stage::Round, Stage::Solve];\n",
    );
    let bad = tree(&[
        ("rust/src/trace/mod.rs", src),
        ("rust/src/engine.rs", "pub fn f() { probe(Stage::Round); }\n"),
    ]);
    let report = run_all(&bad);
    let hits = report.by_rule("stage-coverage");
    assert_eq!(hits.len(), 1, "{:?}", hits);
    assert!(hits[0].msg.contains("Stage::Solve"));

    let good = tree(&[
        ("rust/src/trace/mod.rs", src),
        (
            "rust/src/engine.rs",
            "pub fn f() { probe(Stage::Round); probe(Stage::Solve); }\n",
        ),
    ]);
    assert!(run_all(&good).by_rule("stage-coverage").is_empty());
}

#[test]
fn stages_table_must_list_each_variant_once() {
    let bad = tree(&[
        (
            "rust/src/trace/mod.rs",
            concat!(
                "pub enum Stage {\n    Round = 0,\n    Solve = 1,\n}\n",
                "pub const STAGES: [Stage; 2] = [Stage::Round, Stage::Round];\n",
            ),
        ),
        (
            "rust/src/engine.rs",
            "pub fn f() { probe(Stage::Round); probe(Stage::Solve); }\n",
        ),
    ]);
    let report = run_all(&bad);
    assert!(
        report
            .by_rule("stage-coverage")
            .iter()
            .any(|f| f.msg.contains("2 times") || f.msg.contains("0 times")),
        "{:?}",
        report.by_rule("stage-coverage")
    );
}

#[test]
fn untested_wire_error_variant_is_flagged() {
    let enum_src = "pub enum WireError {\n    Truncated(usize),\n    BadMagic,\n}\n";
    let bad = tree(&[
        ("rust/src/coding/message.rs", enum_src),
        (
            "rust/tests/invariants.rs",
            "fn t() { assert_eq!(decode(b), Err(WireError::Truncated(1))); }\n",
        ),
    ]);
    let report = run_all(&bad);
    let hits = report.by_rule("wire-error-tests");
    assert_eq!(hits.len(), 1, "{:?}", hits);
    assert!(hits[0].msg.contains("BadMagic"));

    let good = tree(&[
        ("rust/src/coding/message.rs", enum_src),
        (
            "rust/tests/invariants.rs",
            concat!(
                "fn t() { assert_eq!(decode(b), Err(WireError::Truncated(1))); ",
                "assert_eq!(decode(c), Err(WireError::BadMagic)); }\n",
            ),
        ),
    ]);
    assert!(run_all(&good).by_rule("wire-error-tests").is_empty());
}

#[test]
fn hotpath_marker_bans_clocks_locks_and_allocs() {
    let bad = tree(&[(
        "rust/src/demo.rs",
        concat!(
            "// verifier: hot-path\n",
            "pub fn record(&self) {\n",
            "    let t = std::time::Instant::now();\n",
            "    let v = Vec::new();\n",
            "    let g = self.m.lock().unwrap();\n",
            "}\n",
        ),
    )]);
    let report = run_all(&bad);
    let hits = report.by_rule("trace-hotpath");
    assert!(hits.iter().any(|f| f.msg.contains("clock")), "{:?}", hits);
    assert!(hits.iter().any(|f| f.msg.contains("allocating")), "{:?}", hits);
    assert!(hits.iter().any(|f| f.msg.contains("blocking lock")), "{:?}", hits);

    // try_lock + clock-ok marker is the sanctioned shape.
    let good = tree(&[(
        "rust/src/demo.rs",
        concat!(
            "// verifier: hot-path (clock-ok)\n",
            "pub fn span(&self) {\n",
            "    let t = std::time::Instant::now();\n",
            "    if let Ok(g) = self.m.try_lock() { g.len(); }\n",
            "}\n",
        ),
    )]);
    assert!(run_all(&good).by_rule("trace-hotpath").is_empty());
}

#[test]
fn deprecated_shim_use_is_flagged_outside_its_home() {
    let home = concat!(
        "#[deprecated(note = \"use Session\")]\n",
        "pub struct OldConfig { pub n: usize }\n",
    );
    let bad = tree(&[
        ("rust/src/shims.rs", home),
        (
            "rust/src/caller.rs",
            "pub fn f() -> usize { OldConfig { n: 1 }.n }\n",
        ),
    ]);
    let report = run_all(&bad);
    let hits = report.by_rule("deprecated-use");
    assert_eq!(hits.len(), 1, "{:?}", hits);
    assert!(hits[0].msg.contains("OldConfig"));

    let allowed = tree(&[
        ("rust/src/shims.rs", home),
        (
            "rust/src/caller.rs",
            concat!(
                "#[allow(deprecated)]\n",
                "pub fn f() -> usize { OldConfig { n: 1 }.n }\n",
            ),
        ),
    ]);
    assert!(run_all(&allowed).by_rule("deprecated-use").is_empty());
}

#[test]
fn deprecated_method_shim_matches_only_qualified_uses() {
    // A deprecated associated fn named `new` must match `Cluster::new` but
    // never an unrelated `Vec::new()` / `Other::new()` — the precision that
    // keeps the rule usable when shim names collide with live items.
    let home = concat!(
        "pub struct Cluster;\n",
        "impl Cluster {\n",
        "    #[deprecated(note = \"use Session::cluster\")]\n",
        "    pub fn new() -> Self { Cluster }\n",
        "}\n",
    );
    let bad = tree(&[
        ("rust/src/cluster.rs", home),
        (
            "rust/src/caller.rs",
            "pub fn f() { let _c = Cluster::new(); let _v: Vec<u8> = Vec::new(); }\n",
        ),
    ]);
    let report = run_all(&bad);
    let hits = report.by_rule("deprecated-use");
    assert_eq!(hits.len(), 1, "{:?}", hits);
    assert!(hits[0].msg.contains("Cluster::new"));

    let clean = tree(&[
        ("rust/src/cluster.rs", home),
        (
            "rust/src/caller.rs",
            "pub fn f() { let _v: Vec<u8> = Vec::new(); let _o = Other::new(); }\n",
        ),
    ]);
    assert!(run_all(&clean).by_rule("deprecated-use").is_empty());
}
