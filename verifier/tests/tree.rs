//! Tier-1 gate: the real repo tree must pass every verifier rule. This is
//! what turns "determinism by discipline" into a failing test the moment a
//! refactor drops a SAFETY comment, skews a wire constant, or leaves a
//! `Stage`/`WireError` variant uncovered.

use std::path::PathBuf;

#[test]
fn repo_tree_passes_all_invariants() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("verifier crate sits inside the repo")
        .to_path_buf();
    let tree = verifier::Tree::load(&root).expect("readable rust/ tree");
    assert!(
        tree.files.len() > 20,
        "suspiciously small tree ({} files) — wrong root?",
        tree.files.len()
    );
    let report = verifier::run_all(&tree);
    assert!(
        report.passed(),
        "verifier found violations:\n{}",
        report.render()
    );
}
