"""L1 Pallas kernels for Algorithm 3 (greedy sparsification probabilities).

The paper notes Algorithm 3 "can be easily accelerated on hardware
supporting SIMD"; on TPU the natural home is the VPU. The computation is
element-wise maps plus global reductions, so we structure it as two Pallas
kernels driven by a tiny amount of scalar glue in the surrounding jitted
function (which lowers into the same HLO module):

* [`block_abs_sum`]   — tiled reduction producing per-block Σ|g| partials:
                        one HBM pass over `g`, `BLOCK`-sized VMEM tiles.
* [`scale_clip_stats`] — given the current scale γ, computes
                        `p = min(γ|g|, 1)` for a block AND that block's
                        (Σ_{p<1} p, #capped) partials in the same pass, so
                        each fixed-point iteration reads `g` exactly once.

TPU mapping (DESIGN.md §Hardware-Adaptation): `BlockSpec((BLOCK,), ...)`
expresses the HBM→VMEM streaming schedule; the per-block partials land in
small VMEM outputs reduced by XLA. `interpret=True` everywhere — the CPU
PJRT plugin cannot execute Mosaic custom-calls; real-TPU efficiency is
estimated in EXPERIMENTS.md §Perf from the block shapes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block size: 8 KiB of f32 per tile — comfortably inside VMEM alongside the
# output partials, and a multiple of the VPU lane width (128).
BLOCK = 2048


def _pad_to_block(g):
    d = g.shape[0]
    padded = (d + BLOCK - 1) // BLOCK * BLOCK
    if padded != d:
        g = jnp.pad(g, (0, padded - d))
    return g, padded


def _abs_sum_kernel(g_ref, out_ref):
    out_ref[0] = jnp.sum(jnp.abs(g_ref[...]))


def block_abs_sum(g: jax.Array) -> jax.Array:
    """Σ|g| via a tiled Pallas reduction (returns a scalar)."""
    g, padded = _pad_to_block(g)
    nblocks = padded // BLOCK
    partials = pl.pallas_call(
        _abs_sum_kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nblocks,), jnp.float32),
        interpret=True,
    )(g)
    return jnp.sum(partials)


def _scale_clip_kernel(gamma_ref, g_ref, p_ref, stats_ref):
    gamma = gamma_ref[0]
    p = jnp.minimum(gamma * jnp.abs(g_ref[...]), 1.0)
    p_ref[...] = p
    capped = p >= 1.0
    # stats: [active_sum, capped_count] per block.
    stats_ref[0] = jnp.sum(jnp.where(capped, 0.0, p))
    stats_ref[1] = jnp.sum(jnp.where(capped, 1.0, 0.0))


def scale_clip_stats(g: jax.Array, gamma: jax.Array):
    """One pass: `p = min(γ|g|, 1)` plus (Σ_{p<1} p, #capped) reductions.

    Returns (p, active_sum, capped_count); `p` has the original length.
    """
    d = g.shape[0]
    gp, padded = _pad_to_block(g)
    nblocks = padded // BLOCK
    p, stats = pl.pallas_call(
        _scale_clip_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded,), jnp.float32),
            jax.ShapeDtypeStruct((2 * nblocks,), jnp.float32),
        ],
        interpret=True,
    )(gamma.reshape(1).astype(jnp.float32), gp)
    stats = stats.reshape(nblocks, 2)
    return p[:d], jnp.sum(stats[:, 0]), jnp.sum(stats[:, 1])


@functools.partial(jax.jit, static_argnames=("rho", "iters"))
def greedy_probs(g: jax.Array, rho: float, iters: int = 2):
    """Algorithm 3 built from the Pallas kernels.

    Semantically identical to `ref.greedy_probs_ref` (pytest asserts this
    across shapes/densities via hypothesis). Each iteration streams `g`
    once; total HBM traffic is `(1 + iters) · |g|` reads + `|g|` writes.
    """
    d = g.shape[0]
    g = g.astype(jnp.float32)
    l1 = block_abs_sum(g)
    target = jnp.float32(rho * d)
    safe_l1 = jnp.where(l1 > 0, l1, 1.0)
    gamma = target / safe_l1

    # Fixed-point rescale: gamma *= c where c = want/active_sum (clamped at
    # >= 1). The p from the *final* gamma is recomputed in one last pass so
    # iterations don't need to materialize intermediate p vectors.
    def body(_, gamma):
        _, active_sum, capped = scale_clip_stats(g, gamma)
        want = target - capped
        c = jnp.where(
            (want > 0) & (active_sum > 0), want / jnp.maximum(active_sum, 1e-30), 1.0
        )
        return gamma * jnp.maximum(c, 1.0)

    # NOTE: ref.py applies `iters` rescales after p0; the first stats pass
    # here sees p0, so `iters` loop turns == `iters` rescales. Matches ref.
    gamma = jax.lax.fori_loop(0, iters, body, gamma)
    p, _, _ = scale_clip_stats(g, gamma)
    p = jnp.where(l1 > 0, p, jnp.zeros_like(p))
    inv_lambda = jnp.where(l1 > 0, 1.0 / gamma, 0.0)
    return p, inv_lambda
