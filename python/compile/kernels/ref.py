"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest suite checks `greedy.py` and
`logistic.py` against (and they match the Rust implementations in
`rust/src/sparsify/probs.rs` / `rust/src/model/logistic.rs`, which the
integration tests cross-check through the AOT artifacts).
"""

import jax
import jax.numpy as jnp


def greedy_probs_ref(g: jax.Array, rho: float, iters: int = 2):
    """Algorithm 3 (greedy sparsification probabilities), pure jnp.

    Returns (p, inv_lambda): p_i = min(gamma * |g_i|, 1) after `iters`
    fixed-point rescalings, and inv_lambda = 1/gamma (the shared decoded
    magnitude of survivors with p < 1).
    """
    d = g.shape[0]
    absg = jnp.abs(g).astype(jnp.float32)
    l1 = jnp.sum(absg)
    target = rho * d

    safe_l1 = jnp.where(l1 > 0, l1, 1.0)
    gamma0 = target / safe_l1

    def body(_, carry):
        p, gamma = carry
        capped = jnp.sum(jnp.where(p >= 1.0, 1.0, 0.0))
        active_sum = jnp.sum(jnp.where(p < 1.0, p, 0.0))
        want = target - capped
        c = jnp.where(
            (want > 0) & (active_sum > 0), want / jnp.maximum(active_sum, 1e-30), 1.0
        )
        c = jnp.maximum(c, 1.0)  # c <= 1 means "stop": applying 1 is a no-op
        new_p = jnp.where(p < 1.0, jnp.minimum(p * c, 1.0), p)
        return new_p, gamma * c

    p0 = jnp.minimum(gamma0 * absg, 1.0)
    p, gamma = jax.lax.fori_loop(0, iters, body, (p0, gamma0))
    p = jnp.where(l1 > 0, p, jnp.zeros_like(p))
    inv_lambda = jnp.where(l1 > 0, 1.0 / gamma, 0.0)
    return p, inv_lambda


def logistic_grad_ref(x: jax.Array, y: jax.Array, w: jax.Array, reg: float):
    """Minibatch ℓ2-logistic gradient + loss (eq. 14), pure jnp.

    x: (B, D) f32; y: (B,) f32 in {-1, +1}; w: (D,) f32.
    Returns (grad (D,), loss scalar) — mean-over-batch loss + regularizer.
    """
    margins = y * (x @ w)
    loss = jnp.mean(jnp.logaddexp(0.0, -margins)) + reg * jnp.sum(w * w)
    coef = -jax.nn.sigmoid(-margins) * y / x.shape[0]
    grad = x.T @ coef + 2.0 * reg * w
    return grad, loss


def svm_grad_ref(x: jax.Array, y: jax.Array, w: jax.Array, reg: float):
    """Minibatch hinge-loss SVM subgradient + loss (eq. 16), pure jnp."""
    margins = y * (x @ w)
    loss = jnp.mean(jnp.maximum(1.0 - margins, 0.0)) + reg * jnp.sum(w * w)
    active = (margins < 1.0).astype(x.dtype)
    coef = -active * y / x.shape[0]
    grad = x.T @ coef + 2.0 * reg * w
    return grad, loss
