"""L1 Pallas kernel for the minibatch ℓ2-logistic gradient — the compute
hot-spot of the paper's convex experiments (Figures 1–6).

The hot op pair is `u = X w` followed by `grad = Xᵀ r`: two passes over the
same `B×D` matrix in the naive form. The kernel fuses them so `X` makes
**one** HBM pass: the grid runs over batch tiles; each tile computes its
forward matvec, the sigmoid residual on-VPU, and accumulates its rank-`Bb`
contribution `X_bᵀ r_b` into the output gradient block, exploiting the
TPU's sequential-grid accumulation semantics (`o_ref[...] +=` with an
`@pl.when(first)` init).

TPU mapping (DESIGN.md §Hardware-Adaptation): `X` tiles of `(TB, D)` stream
HBM→VMEM; the matvec pair feeds the MXU with `(TB, D) × (D,)` products;
the full `w`/`grad` vectors persist in VMEM across grid steps (D ≤ 16K
floats = 64 KiB — well inside the ~16 MiB VMEM budget together with the
tiles). `interpret=True` for CPU-PJRT execution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch-tile height: 8 rows of the paper's d=2048 setting = 64 KiB per tile.
TILE_B = 8


def _logistic_tile_kernel(x_ref, y_ref, w_ref, grad_ref, loss_ref, *, batch):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        grad_ref[...] = jnp.zeros_like(grad_ref)
        loss_ref[0] = 0.0

    x = x_ref[...]  # (TILE_B, D)
    y = y_ref[...]  # (TILE_B,)
    w = w_ref[...]  # (D,)
    margins = y * (x @ w)
    # Mean-over-batch scaling folded into the residual.
    coef = -jax.nn.sigmoid(-margins) * y / batch
    grad_ref[...] += x.T @ coef
    loss_ref[0] += jnp.sum(jnp.logaddexp(0.0, -margins)) / batch


@functools.partial(jax.jit, static_argnames=("reg",))
def logistic_grad(x: jax.Array, y: jax.Array, w: jax.Array, reg: float = 0.0):
    """Fused minibatch logistic gradient + loss via the Pallas kernel.

    x: (B, D) with B a multiple of TILE_B (aot.py fixes B per artifact);
    returns (grad (D,), loss scalar) including the ℓ2 term — semantically
    identical to `ref.logistic_grad_ref`.
    """
    b, d = x.shape
    assert b % TILE_B == 0, f"batch {b} must be a multiple of {TILE_B}"
    nblocks = b // TILE_B
    grad, loss = pl.pallas_call(
        functools.partial(_logistic_tile_kernel, batch=b),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((TILE_B, d), lambda i: (i, 0)),
            pl.BlockSpec((TILE_B,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=True,
    )(x.astype(jnp.float32), y.astype(jnp.float32), w.astype(jnp.float32))
    grad = grad + 2.0 * reg * w
    loss = loss[0] + reg * jnp.sum(w * w)
    return grad, loss
