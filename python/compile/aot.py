"""AOT lowering: every L2 computation → `artifacts/<name>.hlo.txt` + a
manifest the Rust runtime reads.

HLO **text** is the interchange format (NOT `lowered.compile().serialize()`
and NOT serialized protos): jax ≥ 0.5 emits HloModuleProtos with 64-bit
instruction ids which this image's xla_extension 0.5.1 rejects; the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README).

Every function is lowered with `return_tuple=True`; the Rust side always
decomposes a tuple. Usage:

    python -m compile.aot --out ../artifacts          # default set
    python -m compile.aot --out ../artifacts --full   # + big CNN variants
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dims_token(shape) -> str:
    if len(shape) == 0:
        return "scalar"
    return "x".join(str(int(d)) for d in shape)


def _dtype_token(dtype) -> str:
    name = jnp.dtype(dtype).name
    return {"float32": "f32", "int32": "i32"}.get(name, name)


class Builder:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest_lines = []
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, in_specs, returns_tuple=True):
        """Lower `fn(*in_specs)` and write `<name>.hlo.txt` + manifest rows."""
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        # Manifest: inputs from the specs; outputs from an abstract eval.
        for i, spec in enumerate(in_specs):
            self.manifest_lines.append(
                f"{name} in {i} {_dtype_token(spec.dtype)} {_dims_token(spec.shape)}"
            )
        outs = jax.eval_shape(fn, *in_specs)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        flat, _ = jax.tree_util.tree_flatten(outs)
        for i, o in enumerate(flat):
            self.manifest_lines.append(
                f"{name} out {i} {_dtype_token(o.dtype)} {_dims_token(o.shape)}"
            )
        print(f"  {name}: {len(text)} chars, {len(in_specs)} in / {len(flat)} out")
        del returns_tuple

    def finish(self):
        path = os.path.join(self.out_dir, "manifest.txt")
        with open(path, "w") as f:
            f.write("# artifact manifest — see rust/src/runtime/manifest.rs\n")
            f.write("\n".join(self.manifest_lines) + "\n")
        print(f"wrote {path} ({len(self.manifest_lines)} rows)")


F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_all(out_dir: str, full: bool, e2e_dmodel: int, e2e_layers: int, e2e_seq: int):
    b = Builder(out_dir)

    # --- convex workloads (paper defaults: d=2048, per-worker batch 8) ---
    d, batch = 2048, 8
    reg = 1.0 / (10.0 * 1024.0)
    print("lowering convex artifacts...")
    b.emit(
        "logistic_grad",
        functools.partial(model.logistic_step, reg=reg),
        [spec((batch, d)), spec((batch,)), spec((d,))],
    )
    b.emit(
        "logistic_grad_probs",
        functools.partial(model.logistic_grad_probs, reg=reg, rho=0.1),
        [spec((batch, d)), spec((batch,)), spec((d,))],
    )
    b.emit(
        "svm_grad",
        functools.partial(model.svm_step, reg=0.1),
        [spec((batch, 256)), spec((batch,)), spec((256,))],
    )
    b.emit(
        "greedy_probs",
        functools.partial(model.greedy_probs_standalone, rho=0.1),
        [spec((d,))],
    )

    # --- CNNs (§5.2) ---
    cnn_batch = 16
    channel_set = [24, 32] + ([48, 64] if full else [])
    for ch in channel_set:
        print(f"lowering cnn{ch}...")
        nparams = model.cnn_param_shapes(ch)
        param_specs = [spec(s) for _, s in nparams]
        b.emit(
            f"cnn{ch}_init",
            functools.partial(model.cnn_init, channels=ch),
            [spec((), I32)],
        )
        b.emit(
            f"cnn{ch}_step",
            functools.partial(model.cnn_step, channels=ch),
            param_specs + [spec((cnn_batch, 3 * 32 * 32)), spec((cnn_batch,), I32)],
        )

    # --- transformer (e2e) ---
    vocab = 64
    print("lowering transformer...")
    tshapes = model.transformer_param_shapes(vocab, e2e_dmodel, e2e_layers, e2e_seq)
    tspecs = [spec(s) for _, s in tshapes]
    tb = 4
    b.emit(
        "transformer_init",
        functools.partial(
            model.transformer_init,
            vocab=vocab,
            d_model=e2e_dmodel,
            n_layers=e2e_layers,
            seq=e2e_seq,
        ),
        [spec((), I32)],
    )
    b.emit(
        "transformer_step",
        functools.partial(
            model.transformer_step,
            vocab=vocab,
            d_model=e2e_dmodel,
            n_layers=e2e_layers,
            seq=e2e_seq,
        ),
        tspecs + [spec((tb, e2e_seq), I32), spec((tb, e2e_seq), I32)],
    )

    b.finish()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--full", action="store_true", help="also build cnn48/cnn64")
    ap.add_argument("--e2e-dmodel", type=int, default=128)
    ap.add_argument("--e2e-layers", type=int, default=2)
    ap.add_argument("--e2e-seq", type=int, default=64)
    args = ap.parse_args()
    build_all(args.out, args.full, args.e2e_dmodel, args.e2e_layers, args.e2e_seq)


if __name__ == "__main__":
    main()
