"""L2 — the paper's training computations in JAX, calling the L1 kernels.

Everything here is lowered once by `aot.py` to HLO text and executed from
Rust via PJRT; Python never runs on the training path.

Artifact conventions (consumed by `rust/src/model/hlo.rs`):
* `<name>_step(params..., x, y) -> (loss, grads...)` — one gradient step's
  worth of computation; one gradient tensor per parameter tensor, so the
  Rust coordinator can sparsify **per layer** exactly as §5.2 prescribes.
* `<name>_init(seed) -> (params...)` — deterministic initialization.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.greedy import greedy_probs
from .kernels.logistic import logistic_grad
from .kernels import ref

# ---------------------------------------------------------------------------
# Convex models (Figures 1–6, 9): thin wrappers over the L1 kernels.
# ---------------------------------------------------------------------------


def logistic_step(x, y, w, *, reg: float):
    """(grad, loss) for ℓ2-logistic regression — Pallas kernel inside."""
    grad, loss = logistic_grad(x, y, w, reg)
    return grad, loss


def logistic_grad_probs(x, y, w, *, reg: float, rho: float, iters: int = 2):
    """Fused hot path: gradient AND Algorithm-3 probabilities in one HLO
    module (grad computed by the Pallas logistic kernel, p by the Pallas
    greedy kernels). Returns (grad, loss, p, inv_lambda)."""
    grad, loss = logistic_grad(x, y, w, reg)
    p, inv_lambda = greedy_probs(grad, rho, iters)
    return grad, loss, p, inv_lambda


def svm_step(x, y, w, *, reg: float):
    """(grad, loss) for the hinge-loss SVM (pure jnp — the async engine's
    hot path is the Rust implementation; this artifact cross-checks it)."""
    grad, loss = ref.svm_grad_ref(x, y, w, reg)
    return grad, loss


def greedy_probs_standalone(g, *, rho: float, iters: int = 2):
    """The L1 greedy kernel as its own artifact (L3 cross-validates its Rust
    implementation against this through PJRT)."""
    return greedy_probs(g, rho, iters)


# ---------------------------------------------------------------------------
# CNN (§5.2): 3 conv(3x3) + BN layers, 2 maxpools, FC-256, FC-10.
# ---------------------------------------------------------------------------


def cnn_param_shapes(channels: int, image: int = 32, classes: int = 10):
    """Parameter tensors, in order. BN is folded to a per-channel (scale,
    bias) pair learned with batch statistics."""
    c = channels
    feat = (image // 4) * (image // 4) * c  # two 2x2 pools
    return [
        ("conv1_w", (3, 3, 3, c)),
        ("bn1_sb", (2, c)),
        ("conv2_w", (3, 3, c, c)),
        ("bn2_sb", (2, c)),
        ("conv3_w", (3, 3, c, c)),
        ("bn3_sb", (2, c)),
        ("fc1_w", (feat, 256)),
        ("fc1_b", (256,)),
        ("fc2_w", (256, classes)),
        ("fc2_b", (classes,)),
    ]


def cnn_init(seed, *, channels: int):
    key = jax.random.PRNGKey(seed.astype(jnp.int32) if hasattr(seed, "astype") else seed)
    params = []
    for name, shape in cnn_param_shapes(channels):
        key, sub = jax.random.split(key)
        if name.endswith("_w"):
            fan_in = 1
            for s in shape[:-1]:
                fan_in *= int(s)
            params.append(
                jax.random.normal(sub, shape, jnp.float32) * (2.0 / fan_in) ** 0.5
            )
        elif name.endswith("_sb"):
            sb = jnp.zeros(shape, jnp.float32)
            params.append(sb.at[0].set(1.0))  # scale=1, bias=0
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return tuple(params)


def _conv_bn_relu(x, w, sb):
    # NHWC, SAME padding, stride 1.
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    mean = jnp.mean(y, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(y, axis=(0, 1, 2), keepdims=True)
    y = (y - mean) / jnp.sqrt(var + 1e-5)
    y = y * sb[0] + sb[1]
    return jax.nn.relu(y)


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def cnn_forward(params, x):
    """x: (B, 3*32*32) flat CHW (the Rust side's layout) → logits (B, 10)."""
    b = x.shape[0]
    img = x.reshape(b, 3, 32, 32).transpose(0, 2, 3, 1)  # CHW -> NHWC
    c1w, bn1, c2w, bn2, c3w, bn3, f1w, f1b, f2w, f2b = params
    h = _conv_bn_relu(img, c1w, bn1)
    h = _maxpool2(h)
    h = _conv_bn_relu(h, c2w, bn2)
    h = _maxpool2(h)
    h = _conv_bn_relu(h, c3w, bn3)
    h = h.reshape(b, -1)
    h = jax.nn.relu(h @ f1w + f1b)
    return h @ f2w + f2b


def cnn_loss(params, x, y):
    logits = cnn_forward(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def cnn_step(*args, channels: int):
    """(params..., x, y) -> (loss, grads...)."""
    nparams = len(cnn_param_shapes(channels))
    params = tuple(args[:nparams])
    x, y = args[nparams], args[nparams + 1]
    loss, grads = jax.value_and_grad(cnn_loss)(params, x, y)
    return (loss,) + tuple(grads)


# ---------------------------------------------------------------------------
# Transformer LM (end-to-end example): pre-LN decoder-only, byte-level.
# ---------------------------------------------------------------------------


def transformer_param_shapes(vocab: int, d_model: int, n_layers: int, seq: int):
    shapes = [("embed", (vocab, d_model)), ("pos", (seq, d_model))]
    for l in range(n_layers):
        shapes += [
            (f"l{l}_ln1", (2, d_model)),
            (f"l{l}_qkv", (d_model, 3 * d_model)),
            (f"l{l}_attn_out", (d_model, d_model)),
            (f"l{l}_ln2", (2, d_model)),
            (f"l{l}_mlp_in", (d_model, 4 * d_model)),
            (f"l{l}_mlp_out", (4 * d_model, d_model)),
        ]
    shapes += [("ln_f", (2, d_model))]
    return shapes


def transformer_init(seed, *, vocab: int, d_model: int, n_layers: int, seq: int):
    key = jax.random.PRNGKey(seed.astype(jnp.int32) if hasattr(seed, "astype") else seed)
    params = []
    for name, shape in transformer_param_shapes(vocab, d_model, n_layers, seq):
        key, sub = jax.random.split(key)
        if name.endswith("ln1") or name.endswith("ln2") or name == "ln_f":
            p = jnp.zeros(shape, jnp.float32).at[0].set(1.0)
        else:
            scale = 0.02
            p = jax.random.normal(sub, shape, jnp.float32) * scale
        params.append(p)
    return tuple(params)


def _ln(x, sb):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    sd = jnp.sqrt(jnp.var(x, axis=-1, keepdims=True) + 1e-5)
    return (x - mu) / sd * sb[0] + sb[1]


def transformer_forward(params, tokens, *, n_layers: int, n_heads: int = 4):
    embed, pos = params[0], params[1]
    b, s = tokens.shape
    d_model = embed.shape[1]
    h = embed[tokens] + pos[None, :s, :]
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    per_layer = 6
    for l in range(n_layers):
        ln1, qkv_w, out_w, ln2, mlp_in, mlp_out = params[2 + l * per_layer : 2 + (l + 1) * per_layer]
        x = _ln(h, ln1)
        qkv = x @ qkv_w  # (B, S, 3D)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        hd = d_model // n_heads

        def heads(t):
            return t.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)

        q, k, v = heads(q), heads(k), heads(v)
        att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(hd))
        att = jnp.where(mask[None, None] > 0, att, -1e9)
        att = jax.nn.softmax(att, axis=-1)
        o = (att @ v).transpose(0, 2, 1, 3).reshape(b, s, d_model)
        h = h + o @ out_w
        x = _ln(h, ln2)
        h = h + jax.nn.gelu(x @ mlp_in) @ mlp_out
    return _ln(h, params[-1]) @ embed.T  # tied softmax


def transformer_loss(params, tokens, targets, *, n_layers: int):
    logits = transformer_forward(params, tokens, n_layers=n_layers)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def transformer_step(*args, vocab: int, d_model: int, n_layers: int, seq: int):
    """(params..., tokens, targets) -> (loss, grads...)."""
    nparams = len(transformer_param_shapes(vocab, d_model, n_layers, seq))
    params = tuple(args[:nparams])
    tokens, targets = args[nparams], args[nparams + 1]
    loss, grads = jax.value_and_grad(
        functools.partial(transformer_loss, n_layers=n_layers)
    )(params, tokens, targets)
    return (loss,) + tuple(grads)
