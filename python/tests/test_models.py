"""L2 model correctness: CNN and transformer step functions (shapes,
gradient sanity, loss decrease under a few SGD steps)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def test_cnn_shapes_and_step():
    ch = 24
    params = model.cnn_init(jnp.int32(0), channels=ch)
    shapes = model.cnn_param_shapes(ch)
    assert len(params) == len(shapes)
    for p, (name, s) in zip(params, shapes):
        assert p.shape == s, name
    b = 16
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(b, 3 * 32 * 32)).astype(np.float32) * 0.5)
    y = jnp.asarray(rng.integers(0, 10, size=b).astype(np.int32))
    out = model.cnn_step(*params, x, y, channels=ch)
    loss, grads = out[0], out[1:]
    assert np.isfinite(float(loss))
    assert len(grads) == len(params)
    for g, p in zip(grads, params):
        assert g.shape == p.shape
        assert np.all(np.isfinite(np.asarray(g)))
    # Initial loss ≈ ln(10) for 10 balanced classes.
    assert abs(float(loss) - np.log(10)) < 1.0


def test_cnn_loss_decreases_with_sgd():
    ch = 24
    params = list(model.cnn_init(jnp.int32(1), channels=ch))
    rng = np.random.default_rng(1)
    b = 16
    x = jnp.asarray(rng.normal(size=(b, 3 * 32 * 32)).astype(np.float32) * 0.5)
    y = jnp.asarray(rng.integers(0, 10, size=b).astype(np.int32))
    step = jax.jit(lambda *a: model.cnn_step(*a, channels=ch))
    first = None
    for _ in range(15):
        out = step(*params, x, y)
        loss, grads = out[0], out[1:]
        if first is None:
            first = float(loss)
        params = [p - 0.05 * g for p, g in zip(params, grads)]
    assert float(loss) < first * 0.8, f"{first} -> {float(loss)}"


def test_cnn_gradient_matches_finite_difference():
    ch = 24
    params = list(model.cnn_init(jnp.int32(2), channels=ch))
    rng = np.random.default_rng(2)
    b = 16
    x = jnp.asarray(rng.normal(size=(b, 3 * 32 * 32)).astype(np.float32) * 0.5)
    y = jnp.asarray(rng.integers(0, 10, size=b).astype(np.int32))
    out = model.cnn_step(*params, x, y, channels=ch)
    g_fc2b = np.asarray(out[1 + 9])  # fc2_b gradient
    # Finite differences on two coordinates of fc2_b.
    for i in [0, 7]:
        eps = 1e-3
        pp = [p for p in params]
        pp[9] = params[9].at[i].add(eps)
        lp = float(model.cnn_loss(tuple(pp), x, y))
        pm = [p for p in params]
        pm[9] = params[9].at[i].add(-eps)
        lm = float(model.cnn_loss(tuple(pm), x, y))
        num = (lp - lm) / (2 * eps)
        assert abs(num - g_fc2b[i]) < 5e-3 * (1 + abs(num)), f"coord {i}"


def test_transformer_shapes_and_learning():
    vocab, d_model, n_layers, seq = 64, 32, 2, 16
    params = list(
        model.transformer_init(
            jnp.int32(0), vocab=vocab, d_model=d_model, n_layers=n_layers, seq=seq
        )
    )
    shapes = model.transformer_param_shapes(vocab, d_model, n_layers, seq)
    assert len(params) == len(shapes)
    rng = np.random.default_rng(3)
    b = 4
    tokens = jnp.asarray(rng.integers(0, vocab, size=(b, seq)).astype(np.int32))
    targets = jnp.asarray(rng.integers(0, vocab, size=(b, seq)).astype(np.int32))
    step = jax.jit(
        lambda *a: model.transformer_step(
            *a, vocab=vocab, d_model=d_model, n_layers=n_layers, seq=seq
        )
    )
    out = step(*params, tokens, targets)
    loss0 = float(out[0])
    # Initial loss ≈ uniform ln(64).
    assert abs(loss0 - np.log(vocab)) < 0.5
    # Memorize one batch.
    for _ in range(30):
        out = step(*params, tokens, targets)
        grads = out[1:]
        params = [p - 0.5 * g for p, g in zip(params, grads)]
    assert float(out[0]) < loss0 * 0.7, f"{loss0} -> {float(out[0])}"


def test_transformer_causality():
    # Changing a future token must not affect earlier logits.
    vocab, d_model, n_layers, seq = 64, 32, 1, 8
    params = model.transformer_init(
        jnp.int32(4), vocab=vocab, d_model=d_model, n_layers=n_layers, seq=seq
    )
    tokens = jnp.zeros((1, seq), jnp.int32)
    logits_a = model.transformer_forward(params, tokens, n_layers=n_layers)
    tokens_b = tokens.at[0, seq - 1].set(5)
    logits_b = model.transformer_forward(params, tokens_b, n_layers=n_layers)
    np.testing.assert_allclose(
        logits_a[0, : seq - 1], logits_b[0, : seq - 1], rtol=1e-5, atol=1e-6
    )
    assert not np.allclose(logits_a[0, seq - 1], logits_b[0, seq - 1])
