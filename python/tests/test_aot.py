"""AOT pipeline checks: lowering produces parseable HLO text and a manifest
consistent with the emitted functions' shapes."""

import os

import jax
import jax.numpy as jnp

from compile import aot


def test_hlo_text_roundtrips_through_parser(tmp_path):
    def fn(x, y):
        return (x @ y + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # The text form must carry an entry computation with a tuple root.
    assert "ENTRY" in text
    assert "tuple" in text.lower()


def test_builder_emits_manifest(tmp_path):
    b = aot.Builder(str(tmp_path))

    def fn(x):
        return (x * 2.0, jnp.sum(x))

    b.emit("double", fn, [jax.ShapeDtypeStruct((8,), jnp.float32)])
    b.finish()
    hlo = (tmp_path / "double.hlo.txt").read_text()
    assert "HloModule" in hlo
    manifest = (tmp_path / "manifest.txt").read_text()
    assert "double in 0 f32 8" in manifest
    assert "double out 0 f32 8" in manifest
    assert "double out 1 f32 scalar" in manifest


def test_dims_tokens():
    assert aot._dims_token(()) == "scalar"
    assert aot._dims_token((3, 4)) == "3x4"
    assert aot._dtype_token(jnp.float32) == "f32"
    assert aot._dtype_token(jnp.int32) == "i32"


def test_repo_artifacts_exist_and_match_manifest():
    """After `make artifacts`, every manifest entry has its .hlo.txt."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(art, "manifest.txt")
    if not os.path.exists(manifest):
        import pytest

        pytest.skip("artifacts not built yet (run `make artifacts`)")
    names = set()
    with open(manifest) as f:
        for line in f:
            line = line.split("#")[0].strip()
            if line:
                names.add(line.split()[0])
    assert names, "manifest is empty"
    for name in names:
        path = os.path.join(art, f"{name}.hlo.txt")
        assert os.path.exists(path), f"missing artifact {name}"
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, f"bad HLO text in {name}"
