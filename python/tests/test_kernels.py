"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

Hypothesis sweeps shapes, densities and value distributions — the CORE
correctness signal for the AOT artifacts the Rust coordinator executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.greedy import greedy_probs, block_abs_sum, scale_clip_stats
from compile.kernels.logistic import logistic_grad, TILE_B


def gradient_like(rng: np.random.Generator, d: int, density: float) -> jnp.ndarray:
    mask = rng.random(d) < density
    big = rng.random(d) < 0.1
    vals = rng.normal(size=d) * np.where(big, 5.0, 0.05)
    return jnp.asarray((vals * mask).astype(np.float32))


# ---------------------------------------------------------------------------
# greedy kernel
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    d=st.integers(min_value=1, max_value=6000),
    density=st.floats(min_value=0.05, max_value=1.0),
    rho=st.floats(min_value=0.01, max_value=1.0),
    iters=st.integers(min_value=0, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_greedy_matches_ref(d, density, rho, iters, seed):
    rng = np.random.default_rng(seed)
    g = gradient_like(rng, d, density)
    p_k, il_k = greedy_probs(g, float(rho), int(iters))
    p_r, il_r = ref.greedy_probs_ref(g, float(rho), int(iters))
    np.testing.assert_allclose(p_k, p_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(il_k, il_r, rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    d=st.integers(min_value=1, max_value=5000),
    rho=st.floats(min_value=0.01, max_value=0.9),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_greedy_invariants(d, rho, seed):
    rng = np.random.default_rng(seed)
    g = gradient_like(rng, d, 0.5)
    p, inv_lambda = greedy_probs(g, float(rho), 2)
    p = np.asarray(p)
    assert p.shape == (d,)
    assert np.all(p >= 0.0) and np.all(p <= 1.0 + 1e-6)
    # zero coords get p = 0, non-zero coords get p > 0
    gz = np.asarray(g) == 0.0
    assert np.all(p[gz] == 0.0)
    assert np.all(p[~gz] > 0.0)
    # density never overshoots the target (beyond fp slack)
    assert p.sum() <= rho * d * (1.0 + 1e-4) + 1e-3
    if np.any(~gz):
        assert float(inv_lambda) > 0.0
        # Prop-1 form: p = min(|g|/inv_lambda, 1)
        expect = np.minimum(np.abs(np.asarray(g)) / float(inv_lambda), 1.0)
        np.testing.assert_allclose(p, expect, rtol=2e-4, atol=2e-6)


def test_greedy_zero_gradient():
    p, il = greedy_probs(jnp.zeros(100, jnp.float32), 0.3, 2)
    assert float(jnp.sum(p)) == 0.0
    assert float(il) == 0.0


def test_block_abs_sum_matches_jnp():
    rng = np.random.default_rng(7)
    for d in [1, 5, 2048, 2049, 7000]:
        g = jnp.asarray(rng.normal(size=d).astype(np.float32))
        np.testing.assert_allclose(
            block_abs_sum(g), jnp.sum(jnp.abs(g)), rtol=1e-5
        )


def test_scale_clip_stats_consistency():
    rng = np.random.default_rng(8)
    g = jnp.asarray(rng.normal(size=3000).astype(np.float32))
    gamma = jnp.float32(2.5)
    p, active_sum, capped = scale_clip_stats(g, gamma)
    expect_p = np.minimum(2.5 * np.abs(np.asarray(g)), 1.0)
    np.testing.assert_allclose(p, expect_p, rtol=1e-6)
    np.testing.assert_allclose(
        float(active_sum), expect_p[expect_p < 1.0].sum(), rtol=1e-4
    )
    assert int(capped) == int((expect_p >= 1.0).sum())


# ---------------------------------------------------------------------------
# logistic kernel
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    nb=st.integers(min_value=1, max_value=6),
    d=st.integers(min_value=2, max_value=700),
    reg=st.floats(min_value=0.0, max_value=0.5),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_logistic_matches_ref(nb, d, reg, seed):
    rng = np.random.default_rng(seed)
    b = nb * TILE_B
    x = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    y = jnp.asarray(np.sign(rng.normal(size=b)).astype(np.float32))
    w = jnp.asarray((rng.normal(size=d) * 0.2).astype(np.float32))
    gk, lk = logistic_grad(x, y, w, float(reg))
    gr, lr = ref.logistic_grad_ref(x, y, w, float(reg))
    np.testing.assert_allclose(gk, gr, rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(lk, lr, rtol=1e-4, atol=1e-6)


def test_logistic_matches_autodiff():
    # The analytic gradient must equal jax.grad of the loss.
    rng = np.random.default_rng(9)
    b, d = 16, 64
    x = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    y = jnp.asarray(np.sign(rng.normal(size=b)).astype(np.float32))
    w = jnp.asarray((rng.normal(size=d) * 0.3).astype(np.float32))
    reg = 0.01

    def loss_fn(w):
        return ref.logistic_grad_ref(x, y, w, reg)[1]

    g_auto = jax.grad(loss_fn)(w)
    g_kernel, _ = logistic_grad(x, y, w, reg)
    np.testing.assert_allclose(g_kernel, g_auto, rtol=2e-4, atol=1e-5)


def test_logistic_rejects_ragged_batch():
    with pytest.raises(AssertionError):
        logistic_grad(
            jnp.zeros((TILE_B + 1, 8), jnp.float32),
            jnp.zeros((TILE_B + 1,), jnp.float32),
            jnp.zeros((8,), jnp.float32),
        )


def test_svm_ref_matches_autodiff_away_from_kink():
    rng = np.random.default_rng(10)
    b, d = 12, 32
    x = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    y = jnp.asarray(np.sign(rng.normal(size=b)).astype(np.float32))
    w = jnp.asarray((rng.normal(size=d) * 0.01).astype(np.float32))

    def loss_fn(w):
        return ref.svm_grad_ref(x, y, w, 0.05)[1]

    g_auto = jax.grad(loss_fn)(w)
    g_ref, _ = ref.svm_grad_ref(x, y, w, 0.05)
    np.testing.assert_allclose(g_ref, g_auto, rtol=1e-4, atol=1e-6)
